//! Deterministic PRNG (rand is unavailable offline).
//!
//! SplitMix64 core — tiny, fast, passes BigCrush for these purposes — with
//! helpers for uniform ranges, normal deviates (Box-Muller), shuffles and
//! categorical sampling. Every workload generator, the weight initializer
//! and the samplers take an explicit `Rng` so runs are reproducible from a
//! single seed.

/// SplitMix64 generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// Cached second Box-Muller deviate.
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng {
            state: seed,
            spare: None,
        }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Rejection-free multiply-shift; bias is negligible for n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard normal deviate (Box-Muller, cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare.take() {
            return v;
        }
        loop {
            let u = self.next_f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let r = (-2.0 * u.ln()).sqrt();
            let t = 2.0 * std::f64::consts::PI * self.next_f64();
            self.spare = Some(r * t.sin());
            return r * t.cos();
        }
    }

    /// Fill a slice with N(0, scale^2) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for x in out.iter_mut() {
            *x = self.normal() as f32 * scale;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample an index proportionally to `weights` (all >= 0, sum > 0).
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Derive an independent stream (for per-worker rngs).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.range(3, 9);
            assert!((3..=9).contains(&x));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(11);
        let mean: f64 = (0..20_000).map(|_| r.next_f64()).sum::<f64>() / 20_000.0;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let xs: Vec<f64> = (0..40_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[r.categorical(&[1.0, 2.0, 7.0])] += 1;
        }
        assert!(counts[2] > counts[1] && counts[1] > counts[0]);
        let frac2 = counts[2] as f64 / 30_000.0;
        assert!((frac2 - 0.7).abs() < 0.03, "frac2={frac2}");
    }

    #[test]
    fn fork_streams_independent() {
        let mut r = Rng::new(1);
        let mut f1 = r.fork();
        let mut f2 = r.fork();
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
