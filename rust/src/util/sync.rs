//! Concurrency-primitive shims: std by default, `loom` under `--cfg loom`.
//!
//! Everything concurrency-sensitive in the serving layer
//! (`engine::server`'s router lock, shard queue-depth counters, worker
//! spawning) imports its primitives from here instead of `std::sync`,
//! so the same code compiles against the [loom] model checker's
//! instrumented types when built with `RUSTFLAGS="--cfg loom"`. The
//! in-tree `rust/loom-stub` keeps that build hermetic (it re-exports
//! std under loom's paths and runs models on real threads); patching in
//! the real loom crate upgrades the model tests in
//! `rust/tests/loom_sync.rs` to exhaustive interleaving exploration
//! with no source change.
//!
//! mpsc channels intentionally stay `std::sync::mpsc` in both builds:
//! loom models them poorly and the repo treats channel transfer as a
//! trusted primitive; the properties under test are the lock/atomic
//! protocols *around* the channels.
//!
//! [loom]: https://docs.rs/loom

#[cfg(not(loom))]
pub use std::sync::{Arc, Mutex, MutexGuard};

#[cfg(loom)]
pub use loom::sync::{Arc, Mutex, MutexGuard};

#[cfg(not(loom))]
pub mod atomic {
    pub use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
}

#[cfg(loom)]
pub mod atomic {
    pub use loom::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
}

#[cfg(not(loom))]
pub mod thread {
    pub use std::thread::{spawn, yield_now, JoinHandle};
}

#[cfg(loom)]
pub mod thread {
    pub use loom::thread::{spawn, yield_now, JoinHandle};
}

/// Run a concurrency model test body.
///
/// Under `--cfg loom` this is `loom::model(f)` — with the real loom
/// patched in, every legal interleaving of the body's loom-typed
/// operations is explored. In the default build the body simply runs
/// once on real threads, so the model tests double as live regression
/// tests in plain `cargo test`.
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    #[cfg(loom)]
    loom::model(f);
    #[cfg(not(loom))]
    f();
}
