//! Tiny typed CLI parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments; typed getters with defaults; and auto-generated usage text.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// Parsed arguments: options (last occurrence wins), flags, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(
        iter: I,
        known_flags: &[&str],
    ) -> Result<Args, CliError> {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest is positional.
                    args.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&body) {
                    args.flags.push(body.to_string());
                } else if let Some(next) = it.peek() {
                    if next.starts_with("--") {
                        return Err(CliError(format!("option --{body} needs a value")));
                    }
                    args.opts.insert(body.to_string(), it.next().unwrap());
                } else {
                    return Err(CliError(format!("option --{body} needs a value")));
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected integer, got '{v}'"))),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: expected number, got '{v}'"))),
        }
    }

    /// Comma-separated list of integers, e.g. `--sizes 512,1024`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>, CliError> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|_| CliError(format!("--{name}: bad element '{p}'")))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str], flags: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn options_and_flags() {
        let a = parse(
            &["--n", "5", "--verbose", "--name=x", "pos1"],
            &["verbose"],
        );
        assert_eq!(a.usize_or("n", 0).unwrap(), 5);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("name"), Some("x"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults() {
        let a = parse(&[], &[]);
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.f64_or("x", 1.5).unwrap(), 1.5);
        assert_eq!(a.str_or("s", "d"), "d");
        assert!(!a.flag("v"));
    }

    #[test]
    fn lists() {
        let a = parse(&["--sizes", "1,2, 3"], &[]);
        assert_eq!(a.usize_list_or("sizes", &[]).unwrap(), vec![1, 2, 3]);
        assert_eq!(a.usize_list_or("other", &[9]).unwrap(), vec![9]);
    }

    #[test]
    fn double_dash_terminator() {
        let a = parse(&["--n", "1", "--", "--not-an-opt"], &[]);
        assert_eq!(a.positional, vec!["--not-an-opt"]);
    }

    #[test]
    fn missing_value_is_error() {
        let r = Args::parse(["--n".to_string()].into_iter(), &[]);
        assert!(r.is_err());
    }

    #[test]
    fn bad_int_is_error() {
        let a = parse(&["--n", "xyz"], &[]);
        assert!(a.usize_or("n", 0).is_err());
    }

    #[test]
    fn last_occurrence_wins() {
        let a = parse(&["--n", "1", "--n", "2"], &[]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 2);
    }
}
