//! Timing harness and report tables (criterion replacement).

use crate::util::json::Json;
use crate::util::stats::{summarize, Summary};
use std::time::Instant;

/// Time `f` with warmup; returns a summary over `iters` runs (ms).
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    summarize(&samples)
}

/// Simple scoped timer.
pub struct BenchTimer(Instant);

impl BenchTimer {
    pub fn start() -> BenchTimer {
        BenchTimer(Instant::now())
    }
    pub fn ms(&self) -> f64 {
        self.0.elapsed().as_secs_f64() * 1e3
    }
}

impl Default for BenchTimer {
    fn default() -> Self {
        Self::start()
    }
}

/// A printable figure/table reproduction.
#[derive(Debug, Clone)]
pub struct FigureReport {
    pub name: String,
    pub description: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes (expected shape vs paper).
    pub notes: Vec<String>,
    /// Optional machine-readable metrics snapshot backing the table
    /// (e.g. `Metrics::to_json` from a serve run): emitted under a
    /// `"metrics"` key in [`FigureReport::to_json`] so CI can assert on
    /// exact counters instead of parsing the rendered cells.
    pub metrics: Option<Json>,
}

impl FigureReport {
    pub fn new(name: &str, description: &str, headers: &[&str]) -> FigureReport {
        FigureReport {
            name: name.to_string(),
            description: description.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: Vec::new(),
            metrics: None,
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// Render an aligned ASCII table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n{}\n", self.name, self.description));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::from_pairs([
            ("name", Json::from(self.name.clone())),
            ("description", Json::from(self.description.clone())),
            (
                "headers",
                Json::Arr(self.headers.iter().map(|h| Json::from(h.clone())).collect()),
            ),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| Json::Arr(r.iter().map(|c| Json::from(c.clone())).collect()))
                        .collect(),
                ),
            ),
            (
                "notes",
                Json::Arr(self.notes.iter().map(|n| Json::from(n.clone())).collect()),
            ),
        ]);
        if let (Json::Obj(map), Some(m)) = (&mut j, &self.metrics) {
            map.insert("metrics".to_string(), m.clone());
        }
        j
    }

    /// Persist under target/bench_results/<name>.json (best effort).
    pub fn save(&self) {
        let dir = "target/bench_results";
        let _ = std::fs::create_dir_all(dir);
        let _ = std::fs::write(
            format!("{dir}/{}.json", self.name),
            crate::util::json::emit(&self.to_json()),
        );
    }
}

/// Format ms with sensible precision.
pub fn fmt_ms(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}")
    } else if x >= 1.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

/// Format a ratio like "3.8x".
pub fn fmt_x(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format bytes as MB/GB.
pub fn fmt_bytes(b: u64) -> String {
    let gb = b as f64 / 1e9;
    if gb >= 1.0 {
        format!("{gb:.2}GB")
    } else {
        format!("{:.1}MB", b as f64 / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures() {
        let s = time_it(1, 5, || {
            std::thread::sleep(std::time::Duration::from_millis(2));
        });
        assert_eq!(s.n, 5);
        assert!(s.mean >= 1.5, "mean = {}", s.mean);
    }

    #[test]
    fn report_renders_aligned() {
        let mut r = FigureReport::new("t", "desc", &["a", "bbbb"]);
        r.row(vec!["1".into(), "2".into()]);
        r.row(vec!["1000".into(), "x".into()]);
        let s = r.render();
        assert!(s.contains("bbbb"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut r = FigureReport::new("t", "d", &["a", "b"]);
        r.row(vec!["only-one".into()]);
    }

    #[test]
    fn metrics_snapshot_round_trips_through_json() {
        let mut r = FigureReport::new("t", "d", &["a"]);
        r.row(vec!["1".into()]);
        assert!(r.to_json().get("metrics").is_none());
        r.metrics = Some(Json::from_pairs([("kv_bytes_read", Json::from(42.0))]));
        let j = r.to_json();
        let m = j.get("metrics").expect("metrics key present");
        assert_eq!(m.get("kv_bytes_read").and_then(Json::as_f64), Some(42.0));
    }

    #[test]
    fn formatters() {
        assert_eq!(fmt_ms(123.4), "123");
        assert_eq!(fmt_ms(3.14159), "3.14");
        assert_eq!(fmt_ms(0.01234), "0.0123");
        assert_eq!(fmt_x(3.799), "3.80x");
        assert_eq!(fmt_bytes(2_500_000_000), "2.50GB");
        assert_eq!(fmt_bytes(3_200_000), "3.2MB");
    }
}
