//! Benchmark harness + per-figure drivers.
//!
//! criterion is unavailable offline, so [`harness`] provides
//! warmup/iteration timing with summary statistics, and [`figures`]
//! implements one driver per paper table/figure (see DESIGN.md §5 for the
//! index). The `benches/` binaries and the `codec` CLI both call into
//! here, so `cargo bench` and `codec bench-fig5` print identical tables.

pub mod figures;
pub mod harness;
pub mod matrix;

pub use harness::{time_it, BenchTimer, FigureReport};
pub use matrix::{run_matrix, MatrixOptions};
