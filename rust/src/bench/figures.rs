//! One driver per paper table/figure (DESIGN.md §5 experiment index).
//!
//! Timing figures replay each system's plan on the gpusim cost model
//! (calibrated to the paper's Table 2 A100 grid); traffic figures use the
//! exact byte accounting; Fig. 11 measures *this machine's* real divider
//! CPU time; Fig. 7 additionally runs the real engine when artifacts are
//! available. Expected *shapes* (who wins, by roughly what factor) match
//! the paper; absolute values are model-derived — see EXPERIMENTS.md.

use super::harness::{fmt_bytes, fmt_ms, fmt_x, FigureReport};
use crate::cost::gpu_specs::{all_specs, A100};
use crate::cost::Estimator;
use crate::gpusim::{sim_cascade, sim_codec, sim_codec_ablated, sim_flash, AblationConfig};
use crate::kvforest::Forest;
use crate::model::config::{gqa_variant, model_sweep, ModelConfig, QWEN3_4B};
use crate::sched::{divide_and_schedule, naive, tasks_from_forest, DividerConfig};
use crate::util::stats::geomean;
use crate::workload::{degenerate_tree, full_kary_tree, shared_ratio_tree, two_level_tree, LoogleCategory, LoogleGen};

/// Default head geometry for the kernel benches (Qwen3-4B).
const HKV: usize = QWEN3_4B.n_kv_heads;
const GROUP: usize = QWEN3_4B.group_size();

fn est_a100() -> Estimator {
    Estimator::table2()
}

/// The paper's Fig. 5 workload suite; returns (label, forest).
pub fn fig5_workloads() -> Vec<(String, Forest)> {
    let mut w = Vec::new();
    for private in [512usize, 1024, 2048, 4096, 8192] {
        w.push((
            format!("seqlen/private={private}"),
            two_level_tree(32, 120_000, private),
        ));
    }
    for bs in [4usize, 8, 16, 32, 64, 128] {
        w.push((format!("batch/bs={bs}"), two_level_tree(bs, 120_000, 1024)));
    }
    for depth in [2usize, 3, 4, 5, 6] {
        w.push((
            format!("depth/d={depth}"),
            full_kary_tree(2, depth, 8192),
        ));
    }
    for ratio in [0.0, 0.25, 0.5, 0.75, 0.9, 0.99] {
        w.push((
            format!("ratio/{:.0}%", ratio * 100.0),
            shared_ratio_tree(32, 120_000, ratio),
        ));
    }
    for (name, arity) in [("2T", 2usize), ("3T", 3), ("4T", 4), ("5T", 5)] {
        w.push((format!("shape/{name}"), full_kary_tree(arity, 3, 8192)));
    }
    w.push(("shape/DT".to_string(), degenerate_tree(8, 8192)));
    // The paper's extreme points: shared:unique 100:1 and large batches
    // (where Fig. 6 reaches its 409.8x maximum).
    w.push((
        "extreme/100:1-bs64".to_string(),
        two_level_tree(64, 100_000, 1_000),
    ));
    w.push((
        "extreme/100:1-bs256".to_string(),
        two_level_tree(256, 100_000, 1_000),
    ));
    w.push((
        "extreme/500:1-bs1024".to_string(),
        two_level_tree(1024, 120_000, 256),
    ));
    w
}

/// Fig. 5: attention-kernel execution time, CoDec vs FlashDecoding.
pub fn fig5_exec_time() -> FigureReport {
    let est = est_a100();
    let mut rep = FigureReport::new(
        "fig5_exec_time",
        "Decode attention time (ms, simulated A100): CoDec vs FlashDecoding (paper: avg 1.9x, up to 3.6x)",
        &["workload", "flash_ms", "codec_ms", "speedup"],
    );
    let mut speedups = Vec::new();
    for (label, f) in fig5_workloads() {
        let codec = sim_codec(&f, HKV, GROUP, &est, &A100);
        let flash = sim_flash(&f, HKV, GROUP, &est, &A100);
        let sp = flash.total_ms() / codec.total_ms();
        speedups.push(sp);
        rep.row(vec![
            label,
            fmt_ms(flash.total_ms()),
            fmt_ms(codec.total_ms()),
            fmt_x(sp),
        ]);
    }
    rep.note(format!(
        "geomean speedup {} (paper mean 1.9x)",
        fmt_x(geomean(&speedups))
    ));
    rep
}

/// Fig. 6: global memory access, CoDec vs FlashDecoding.
pub fn fig6_mem_access() -> FigureReport {
    let est = est_a100();
    let mut rep = FigureReport::new(
        "fig6_mem_access",
        "Attention global-memory traffic: CoDec vs FlashDecoding (paper: 14.7-409.8x lower, avg 120.9x)",
        &["workload", "flash", "codec", "reduction", "pred_nbar"],
    );
    let mut ratios = Vec::new();
    for (label, f) in fig5_workloads() {
        let codec = sim_codec(&f, HKV, GROUP, &est, &A100);
        let flash = sim_flash(&f, HKV, GROUP, &est, &A100);
        let ratio = flash.traffic_bytes as f64 / codec.traffic_bytes as f64;
        ratios.push(ratio);
        rep.row(vec![
            label,
            fmt_bytes(flash.traffic_bytes),
            fmt_bytes(codec.traffic_bytes),
            fmt_x(ratio),
            format!("{:.1}", f.mean_sharing_degree()),
        ]);
    }
    rep.note(format!("geomean reduction {}", fmt_x(geomean(&ratios))));
    rep
}

/// FFN + projections decode-step time model (memory-bound weight read).
fn ffn_step_ms(cfg: &ModelConfig, gpu: &crate::cost::GpuSpec) -> f64 {
    let bytes = cfg.param_count() as f64 * 2.0; // f16 weights read once per step
    bytes / (gpu.mem_bw_gbs * 1e9) * 1e3
}

/// Fig. 7: end-to-end TPOT, CoDec engine vs vLLM-like baseline
/// (simulated at paper scale; `fig7_engine_rows` adds measured rows).
pub fn fig7_tpot() -> FigureReport {
    let est = est_a100();
    let cfg = QWEN3_4B;
    let mut rep = FigureReport::new(
        "fig7_tpot",
        "End-to-end TPOT (ms/token, simulated A100, Qwen3-4B): CoDec vs vLLM-like (paper: avg 3.8x)",
        &["seqlen", "vllm_ms", "codec_ms", "speedup"],
    );
    let mut sps = Vec::new();
    for shared in [20_000usize, 50_000, 100_000, 150_000] {
        let f = two_level_tree(32, shared, 256);
        let codec = sim_codec(&f, cfg.n_kv_heads, cfg.group_size(), &est, &A100);
        let flash = sim_flash(&f, cfg.n_kv_heads, cfg.group_size(), &est, &A100);
        let ffn = ffn_step_ms(&cfg, &A100);
        // Per decode step: all layers' attention + one full weight pass.
        let codec_tpot = codec.total_ms() * cfg.n_layers as f64 + ffn;
        let vllm_tpot = flash.total_ms() * cfg.n_layers as f64 + ffn;
        let sp = vllm_tpot / codec_tpot;
        sps.push(sp);
        rep.row(vec![
            format!("{shared}"),
            fmt_ms(vllm_tpot),
            fmt_ms(codec_tpot),
            fmt_x(sp),
        ]);
    }
    rep.note(format!("geomean speedup {} (paper 3.8x)", fmt_x(geomean(&sps))));
    rep.note("longer contexts shift time into attention, widening the gap (paper §7.2)");
    rep
}

/// Fig. 8: LooGLE categories + cascade comparison across shared ratios.
pub fn fig8_loogle() -> FigureReport {
    let est = est_a100();
    let mut rep = FigureReport::new(
        "fig8_loogle",
        "LooGLE-like corpus + FlashInfer-cascade baseline (paper: CoDec consistently lower latency)",
        &["workload", "flash_ms", "cascade_ms", "codec_ms", "codec_vs_cascade"],
    );
    for cat in LoogleCategory::all() {
        let f = LoogleGen {
            category: cat,
            num_docs: 4,
            questions_per_doc: 10,
            ..Default::default()
        }
        .build_forest();
        let codec = sim_codec(&f, HKV, GROUP, &est, &A100);
        let casc = sim_cascade(&f, HKV, GROUP, &est, &A100);
        let flash = sim_flash(&f, HKV, GROUP, &est, &A100);
        rep.row(vec![
            format!("loogle/{}", cat.name()),
            fmt_ms(flash.total_ms()),
            fmt_ms(casc.total_ms()),
            fmt_ms(codec.total_ms()),
            fmt_x(casc.total_ms() / codec.total_ms()),
        ]);
    }
    for ratio in [0.25, 0.5, 0.75, 0.9, 0.99] {
        let f = shared_ratio_tree(32, 120_000, ratio);
        let codec = sim_codec(&f, HKV, GROUP, &est, &A100);
        let casc = sim_cascade(&f, HKV, GROUP, &est, &A100);
        let flash = sim_flash(&f, HKV, GROUP, &est, &A100);
        rep.row(vec![
            format!("ratio/{:.0}%", ratio * 100.0),
            fmt_ms(flash.total_ms()),
            fmt_ms(casc.total_ms()),
            fmt_ms(codec.total_ms()),
            fmt_x(casc.total_ms() / codec.total_ms()),
        ]);
    }
    rep.note("CoDec < cascade everywhere: global division + round-parallel reduction (§8)");
    rep
}

/// Fig. 9: ablation study on balanced and degenerate 200k-context trees.
pub fn fig9_ablation() -> FigureReport {
    let est = est_a100();
    let mut rep = FigureReport::new(
        "fig9_ablation",
        "Ablation (ms, simulated A100; paper: 26.1x balanced / 10.8x unbalanced full-stack speedup)",
        &["workload", "none", "tree_only", "part_only", "all", "speedup"],
    );
    let balanced = full_kary_tree(2, 6, 200_000 / 6);
    let degen = degenerate_tree(8, 200_000 / 8);
    for (label, f) in [("balanced/2T-d6", &balanced), ("unbalanced/DT-d8", &degen)] {
        let t = |ab: AblationConfig| sim_codec_ablated(f, HKV, GROUP, &est, &A100, ab).total_ms();
        let none = t(AblationConfig::all_off());
        let tree = t(AblationConfig {
            prefix_tree: true,
            partition: false,
            parallel_reduction: false,
        });
        let part = t(AblationConfig {
            prefix_tree: false,
            partition: true,
            parallel_reduction: false,
        });
        let all = t(AblationConfig::all_on());
        rep.row(vec![
            label.to_string(),
            fmt_ms(none),
            fmt_ms(tree),
            fmt_ms(part),
            fmt_ms(all),
            fmt_x(none / all),
        ]);
    }
    rep.note("each optimization strictly reduces latency; combination is largest (paper §7.3)");
    rep
}

/// Fig. 10: division granularity — naive fixed splits vs CoDec adaptive.
pub fn fig10_granularity() -> FigureReport {
    let est = est_a100();
    let mut rep = FigureReport::new(
        "fig10_granularity",
        "Fixed division counts vs CoDec adaptive (paper: adaptive beats best-fixed by 1.02-1.04x, no-division by 3.2-4.4x)",
        &["workload", "div=1", "div=4", "div=16", "div=64", "best_fixed", "codec", "vs_none", "vs_best"],
    );
    let workloads = [
        ("2level/120k", two_level_tree(32, 120_000, 1024)),
        ("degenerate", degenerate_tree(8, 16_384)),
    ];
    for (label, f) in workloads {
        let tasks = tasks_from_forest(&f, HKV, GROUP);
        let mut fixed = Vec::new();
        for splits in [1usize, 4, 16, 64] {
            fixed.push(naive::naive_plan(tasks.clone(), &est, A100.sm_count, splits).makespan_ms);
        }
        let best_fixed = (1..=64)
            .map(|s| naive::naive_plan(tasks.clone(), &est, A100.sm_count, s).makespan_ms)
            .fold(f64::INFINITY, f64::min);
        let codec = divide_and_schedule(
            tasks,
            &est,
            &DividerConfig {
                num_blocks: A100.sm_count,
                ..Default::default()
            },
        )
        .makespan_ms;
        rep.row(vec![
            label.to_string(),
            fmt_ms(fixed[0]),
            fmt_ms(fixed[1]),
            fmt_ms(fixed[2]),
            fmt_ms(fixed[3]),
            fmt_ms(best_fixed),
            fmt_ms(codec),
            fmt_x(fixed[0] / codec),
            fmt_x(best_fixed / codec),
        ]);
    }
    rep
}

/// Fig. 11: real CPU time of computing a division plan vs batch size.
pub fn fig11_division_overhead() -> FigureReport {
    let est = est_a100();
    let mut rep = FigureReport::new(
        "fig11_division_overhead",
        "Division-plan CPU time on this machine (paper: tens of ms at bs=64, amortized over steps)",
        &["batch", "tasks", "plan_ms_mean", "plan_ms_p90"],
    );
    for bs in [1usize, 2, 4, 8, 16, 32, 64] {
        let f = two_level_tree(bs, 120_000, 1024);
        let tasks = tasks_from_forest(&f, HKV, GROUP);
        let ntasks = tasks.len();
        let cfg = DividerConfig {
            num_blocks: A100.sm_count,
            ..Default::default()
        };
        let s = super::harness::time_it(1, 5, || {
            let _ = divide_and_schedule(tasks.clone(), &est, &cfg);
        });
        rep.row(vec![
            format!("{bs}"),
            format!("{ntasks}"),
            fmt_ms(s.mean),
            fmt_ms(s.p90),
        ]);
    }
    rep.note("grows with task count; engine amortizes via plan reuse (§6)");
    rep
}

/// Fig. 12: five GPUs at 50k context.
pub fn fig12_gpus() -> FigureReport {
    let mut rep = FigureReport::new(
        "fig12_gpus",
        "CoDec vs FlashDecoding across GPUs, 50k shared context (paper: H800 4.7x ... A6000 15x)",
        &["gpu", "bw_GBps", "flash_ms", "codec_ms", "speedup"],
    );
    let f = two_level_tree(16, 50_000, 512);
    for gpu in all_specs() {
        let est = est_a100().for_gpu(gpu.clone());
        let codec = sim_codec(&f, HKV, GROUP, &est, &gpu);
        let flash = sim_flash(&f, HKV, GROUP, &est, &gpu);
        rep.row(vec![
            gpu.name.to_string(),
            format!("{:.0}", gpu.mem_bw_gbs),
            fmt_ms(flash.total_ms()),
            fmt_ms(codec.total_ms()),
            fmt_x(flash.total_ms() / codec.total_ms()),
        ]);
    }
    rep.note("gap widens as bandwidth drops (paper §7.6)");
    rep
}

/// Fig. 13: attention variants (GQA group sweep) and model sizes.
pub fn fig13_models() -> FigureReport {
    let est = est_a100();
    let mut rep = FigureReport::new(
        "fig13_models",
        "Attention variants (MHA/GQA/MQA) and model sizes (paper: consistent gains across all)",
        &["config", "kv_heads", "group", "flash_ms", "codec_ms", "speedup", "traffic_red"],
    );
    let f = two_level_tree(16, 50_000, 512);
    for kv in [32usize, 8, 4, 1] {
        let cfg = gqa_variant(kv);
        let codec = sim_codec(&f, cfg.n_kv_heads, cfg.group_size(), &est, &A100);
        let flash = sim_flash(&f, cfg.n_kv_heads, cfg.group_size(), &est, &A100);
        rep.row(vec![
            cfg.name.to_string(),
            format!("{kv}"),
            format!("{}", cfg.group_size()),
            fmt_ms(flash.total_ms()),
            fmt_ms(codec.total_ms()),
            fmt_x(flash.total_ms() / codec.total_ms()),
            fmt_x(flash.traffic_bytes as f64 / codec.traffic_bytes as f64),
        ]);
    }
    for cfg in model_sweep() {
        let codec = sim_codec(&f, cfg.n_kv_heads, cfg.group_size(), &est, &A100);
        let flash = sim_flash(&f, cfg.n_kv_heads, cfg.group_size(), &est, &A100);
        rep.row(vec![
            cfg.name.to_string(),
            format!("{}", cfg.n_kv_heads),
            format!("{}", cfg.group_size()),
            fmt_ms(flash.total_ms() * cfg.n_layers as f64),
            fmt_ms(codec.total_ms() * cfg.n_layers as f64),
            fmt_x(flash.total_ms() / codec.total_ms()),
            fmt_x(flash.traffic_bytes as f64 / codec.traffic_bytes as f64),
        ]);
    }
    rep.note(
        "MQA (group 32) stacks 512 query rows per shared task, past the profiled \
nq grid: the extrapolated cost model prices it at ~parity on time while the \
traffic reduction (the paper's mechanism) stays ~15x — a conservative-model \
artifact, not a CoDec regression (see EXPERIMENTS.md)",
    );
    rep
}

/// Table 2: the cost-profile grid (default = paper values; pass a
/// calibrated profile path via the CLI to print this machine's).
pub fn table2_profile(profile: &crate::cost::Profile) -> FigureReport {
    let mut headers = vec!["n \\ nq".to_string()];
    headers.extend(profile.nq_grid.iter().map(|q| format!("{q}")));
    let mut rep = FigureReport::new(
        "table2_profile",
        &format!(
            "Thread-block execution time (ms), d={} [{}]",
            profile.d, profile.device
        ),
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    for (i, n) in profile.n_grid.iter().enumerate() {
        let mut row = vec![format!("{n}")];
        row.extend(profile.t_ms[i].iter().map(|t| format!("{t:.3}")));
        rep.row(row);
    }
    rep
}

/// Fig. 1b: prefill/decode/attention time breakdown.
pub fn fig1_breakdown() -> FigureReport {
    let est = est_a100();
    let cfg = QWEN3_4B;
    let mut rep = FigureReport::new(
        "fig1_breakdown",
        "Decode-time share of attention as context grows (paper: attention ~90% at 100k)",
        &["context", "attn_ms/step", "ffn_ms/step", "attn_share"],
    );
    for ctx in [8_000usize, 25_000, 50_000, 100_000] {
        let f = two_level_tree(32, ctx, 128);
        let flash = sim_flash(&f, cfg.n_kv_heads, cfg.group_size(), &est, &A100);
        let attn = flash.total_ms() * cfg.n_layers as f64;
        let ffn = ffn_step_ms(&cfg, &A100);
        rep.row(vec![
            format!("{ctx}"),
            fmt_ms(attn),
            fmt_ms(ffn),
            format!("{:.0}%", 100.0 * attn / (attn + ffn)),
        ]);
    }
    rep
}

/// All figure drivers in DESIGN.md order, for `codec bench-all`.
pub fn all_figures() -> Vec<FigureReport> {
    vec![
        fig1_breakdown(),
        table2_profile(&crate::cost::Profile::table2_a100()),
        fig5_exec_time(),
        fig6_mem_access(),
        fig7_tpot(),
        fig8_loogle(),
        fig9_ablation(),
        fig10_granularity(),
        fig11_division_overhead(),
        fig12_gpus(),
        fig13_models(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5_reports_speedup_above_one() {
        let rep = fig5_exec_time();
        assert!(rep.rows.len() >= 20);
        // Geomean note exists and most rows show >= 1x.
        let above: usize = rep
            .rows
            .iter()
            .filter(|r| r[3].trim_end_matches('x').parse::<f64>().unwrap() >= 0.95)
            .count();
        assert!(above as f64 >= rep.rows.len() as f64 * 0.8, "{above}/{}", rep.rows.len());
    }

    #[test]
    fn fig6_reduction_in_paper_range() {
        let rep = fig6_mem_access();
        let ratios: Vec<f64> = rep
            .rows
            .iter()
            .map(|r| r[3].trim_end_matches('x').parse::<f64>().unwrap())
            .collect();
        let max = ratios.iter().cloned().fold(0.0, f64::max);
        assert!(max > 50.0, "max reduction {max}");
    }

    #[test]
    fn fig12_has_all_gpus() {
        let rep = fig12_gpus();
        assert_eq!(rep.rows.len(), 5);
        for r in &rep.rows {
            let sp: f64 = r[4].trim_end_matches('x').parse().unwrap();
            assert!(sp >= 1.0, "{}: {sp}", r[0]);
        }
    }

    #[test]
    fn fig9_full_stack_fastest() {
        let rep = fig9_ablation();
        for r in &rep.rows {
            let none: f64 = r[1].parse().unwrap_or(f64::MAX);
            let all: f64 = r[4].parse().unwrap_or(f64::MAX);
            assert!(all < none);
        }
    }
}
