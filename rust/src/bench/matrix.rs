//! The scenario-matrix harness: every zoo scenario crossed with a small
//! serving-config grid (shards × cache budget × routing policy), each
//! cell a full open-loop serve run.
//!
//! Every cell replays the *same* seeded trace, so greedy outputs must be
//! bit-identical across the whole grid — the baseline cell (1 shard,
//! unbounded cache, affinity routing) is the oracle every other cell is
//! compared against, which is what lets one regression that only one
//! traffic shape exposes fail loudly instead of averaging away. The
//! emitted `BENCH_scenario_matrix.json` carries one row per cell with
//! per-scenario SLO attainment / goodput / prefix hit-rate / memory- and
//! prefill-access-reduction fields; CI's `scenario-matrix` job gates the
//! schema (`codec matrix --quick`), and `cargo bench --bench matrix`
//! runs the standard scale.

use crate::bench::harness::{fmt_x, FigureReport};
use crate::cache::CacheConfig;
use crate::engine::{
    AttentionBackend, EngineConfig, Metrics, RouterConfig, RoutingPolicy, Server, SloTargets,
};
use crate::model::Sampler;
use crate::runtime::ModelInfo;
use crate::util::json::Json;
use crate::workload::zoo::{self, Scenario};
use crate::workload::Trace;
use anyhow::{ensure, Context, Result};

/// Knobs for one matrix run (`codec matrix` and `benches/matrix.rs`
/// both build one of these).
#[derive(Debug, Clone)]
pub struct MatrixOptions {
    /// CI-smoke scale: quick zoo scenarios and a 3-cell grid instead of
    /// the standard scenarios over the full 6-cell grid.
    pub quick: bool,
    /// Seed for every scenario's prompts and arrivals.
    pub seed: u64,
    /// Open-loop Poisson arrival rate each trace is re-timed to.
    pub rate_rps: f64,
    /// SLO targets the per-cell attainment/goodput is judged against.
    pub slo: SloTargets,
    /// Run a single named scenario instead of the whole registry.
    pub scenario: Option<String>,
}

impl Default for MatrixOptions {
    fn default() -> Self {
        MatrixOptions {
            quick: false,
            seed: 1,
            rate_rps: 400.0,
            slo: SloTargets::default(),
            scenario: None,
        }
    }
}

/// One cell of the config grid.
#[derive(Debug, Clone, Copy)]
struct CellSpec {
    shards: usize,
    routing: RoutingPolicy,
    /// Re-run under a data-derived tight page budget with a swap tier,
    /// so eviction/demotion pressure is part of the grid.
    tight: bool,
}

/// The grid. The first cell is always the baseline oracle (1 shard,
/// unbounded, affinity); tight cells come after the unbounded ones so
/// their budget can be derived from the baseline's page high-water mark.
fn cell_specs(quick: bool) -> Vec<CellSpec> {
    use RoutingPolicy::{Affinity, RoundRobin};
    let mut cells = vec![
        CellSpec {
            shards: 1,
            routing: Affinity,
            tight: false,
        },
        CellSpec {
            shards: 2,
            routing: Affinity,
            tight: false,
        },
    ];
    if !quick {
        cells.push(CellSpec {
            shards: 2,
            routing: RoundRobin,
            tight: false,
        });
        cells.push(CellSpec {
            shards: 1,
            routing: Affinity,
            tight: true,
        });
    }
    cells.push(CellSpec {
        shards: 2,
        routing: Affinity,
        tight: true,
    });
    if !quick {
        cells.push(CellSpec {
            shards: 2,
            routing: RoundRobin,
            tight: true,
        });
    }
    cells
}

/// Small-geometry model for matrix runs: tiny transformer dimensions
/// (matrix wall time stays CI-friendly) but a full-size vocabulary, so
/// the zoo's default 100..7100 token span embeds without rescaling.
pub fn bench_model() -> ModelInfo {
    ModelInfo {
        name: "zoo-matrix".to_string(),
        vocab: 8192,
        n_layers: 2,
        n_q_heads: 4,
        n_kv_heads: 2,
        d_head: 16,
        d_ff: 64,
        rope_theta: 10_000.0,
    }
}

fn engine_cfg(page_budget: Option<usize>, swap_budget: Option<usize>) -> EngineConfig {
    EngineConfig {
        backend: AttentionBackend::CodecNative,
        model: bench_model(),
        max_batch: 8,
        sampler: Sampler::Greedy,
        seed: 5,
        workers: 2,
        cache: CacheConfig {
            page_budget,
            swap_budget,
            ..Default::default()
        },
        ..Default::default()
    }
}

fn routing_name(p: RoutingPolicy) -> &'static str {
    match p {
        RoutingPolicy::Affinity => "affinity",
        RoutingPolicy::PowerOfTwo => "p2c",
        RoutingPolicy::RoundRobin => "round-robin",
    }
}

struct CellRun {
    /// Greedy outputs in trace-entry order (replay preserves it: every
    /// zoo trace has nondecreasing arrivals and the sort is stable).
    outputs: Vec<Vec<u32>>,
    metrics: Metrics,
}

fn run_cell(trace: &Trace, spec: CellSpec, budget: Option<(usize, usize)>) -> Result<CellRun> {
    let (page, swap) = match budget {
        Some((p, s)) => (Some(p), Some(s)),
        None => (None, None),
    };
    let cfg = engine_cfg(page, swap);
    let server = if spec.shards > 1 {
        Server::start_sharded(
            cfg,
            spec.shards,
            RouterConfig {
                policy: spec.routing,
                ..Default::default()
            },
        )?
    } else {
        Server::start(cfg)?
    };
    let handles = server.replay(trace);
    let mut outputs = Vec::with_capacity(handles.len());
    for h in handles {
        let id = h.id;
        outputs.push(h.wait().with_context(|| format!("request {id}"))?);
    }
    let report = server.shutdown_report();
    ensure!(
        report.failures.is_empty(),
        "shard failures: {:?}",
        report.failures
    );
    Ok(CellRun {
        outputs,
        metrics: report.metrics,
    })
}

/// Data-derived tight budget for a pressure cell: 80% of the unbounded
/// baseline's page high-water mark, floored so the largest single
/// request (prompt + decode growth, all layers) always fits a shard
/// with headroom — real eviction/demotion pressure, never an infeasible
/// admission. The swap budget is the full baseline peak, so device
/// pressure demotes to the host tier instead of destroying KV.
fn tight_budget(trace: &Trace, baseline: &Metrics, shards: usize) -> (usize, usize) {
    let page_tokens = EngineConfig::default().page_tokens.max(1);
    let n_layers = bench_model().n_layers;
    let max_req_tokens = trace
        .entries
        .iter()
        .map(|e| e.prompt.len() + e.max_new_tokens)
        .max()
        .unwrap_or(1);
    let per_req_pages = n_layers * max_req_tokens.div_ceil(page_tokens) + 2;
    let peak = baseline.kv_max_allocated_pages.max(1);
    let page = (peak * 4 / 5).max(shards * 2 * per_req_pages).max(shards);
    (page, peak)
}

fn lcp(a: &[u32], b: &[u32]) -> usize {
    a.iter().zip(b).take_while(|(x, y)| x == y).count()
}

/// Tokens of each prompt already present in some earlier prompt (its
/// longest common prefix over all earlier entries) — the structural
/// sharing this trace *offers*, which the engine should convert into
/// retained-cache hits or shared-fill dedup. Computed from the trace
/// alone, so the per-scenario gate is seed-robust.
fn structural_shared_tokens(trace: &Trace) -> usize {
    let mut shared = 0;
    for (i, e) in trace.entries.iter().enumerate() {
        shared += trace.entries[..i]
            .iter()
            .map(|p| lcp(&p.prompt, &e.prompt))
            .max()
            .unwrap_or(0);
    }
    shared
}

/// Per-scenario assertion gates, applied to the baseline cell: the
/// structural sharing the trace offers must actually be served shared,
/// and the analytic traffic accounting must stay sane. A regression
/// that only one traffic shape exposes fails here, named.
fn gate_scenario(name: &str, m: &Metrics, structural: usize, logical: usize) -> Result<()> {
    // CoDec must never read more decode bytes than the per-request
    // FlashDecoding baseline would for the same plans.
    if let Some(r) = m.memory_access_reduction() {
        ensure!(
            r >= 0.99,
            "{name}: memory-access reduction {r:.3} < 1 — decode read more than the baseline"
        );
    }
    // Sharing-conversion gate: when ≥ 15% of the trace's tokens are
    // structurally shared, at least half of them must have been served
    // from the retained cache or ridden a coalesced fill.
    if logical > 0 && structural * 100 >= logical * 15 {
        let measured = m.prefill_tokens_shared + m.shared_fill_dedup_tokens;
        ensure!(
            measured * 2 >= structural,
            "{name}: trace offers {structural} structurally shared tokens but only \
             {measured} were served shared — the prefix-sharing path regressed for \
             this traffic shape"
        );
    }
    Ok(())
}

/// Run the whole matrix and return the report; `report.metrics` holds
/// the machine-readable `BENCH_scenario_matrix` payload (schema gated
/// by CI). Every assertion gate runs inside, so both the bench binary
/// and `codec matrix` fail loudly on a regression.
pub fn run_matrix(opts: &MatrixOptions) -> Result<FigureReport> {
    ensure!(
        opts.rate_rps.is_finite() && opts.rate_rps > 0.0,
        "arrival rate must be a positive finite req/s, got {}",
        opts.rate_rps
    );
    let scenarios: Vec<Box<dyn Scenario>> = match &opts.scenario {
        Some(name) => vec![zoo::get(name, opts.seed, opts.quick).with_context(|| {
            format!(
                "unknown scenario '{name}' (registered: {})",
                zoo::SCENARIO_NAMES.join(", ")
            )
        })?],
        None => zoo::all(opts.seed, opts.quick),
    };
    let specs = cell_specs(opts.quick);
    let mut rep = FigureReport::new(
        "BENCH_scenario_matrix",
        "Per-scenario serving matrix: shards × cache budget × routing. Every cell \
         replays the same seeded trace open-loop and must reproduce the baseline \
         cell's greedy outputs bit-identically.",
        &[
            "scenario",
            "shards",
            "routing",
            "budget",
            "finished",
            "SLO%",
            "goodput r/s",
            "hit%",
            "mem x",
            "fill x",
        ],
    );
    let mut scen_json: Vec<Json> = Vec::new();
    for s in &scenarios {
        let trace = s.poisson_trace(opts.rate_rps);
        let logical: usize = trace.entries.iter().map(|e| e.prompt.len()).sum();
        let structural = structural_shared_tokens(&trace);
        let mut baseline: Option<CellRun> = None;
        let mut cells_json: Vec<Json> = Vec::new();
        for spec in &specs {
            let budget = spec.tight.then(|| {
                let base = &baseline.as_ref().expect("baseline cell runs first").metrics;
                tight_budget(&trace, base, spec.shards)
            });
            let run = run_cell(&trace, *spec, budget).with_context(|| {
                format!(
                    "{}: shards={} routing={} tight={}",
                    s.name(),
                    spec.shards,
                    routing_name(spec.routing),
                    spec.tight
                )
            })?;
            ensure!(
                run.outputs.len() == trace.entries.len(),
                "{}: {} of {} requests finished",
                s.name(),
                run.outputs.len(),
                trace.entries.len()
            );
            let matches = baseline
                .as_ref()
                .map(|b| b.outputs == run.outputs)
                .unwrap_or(true);
            ensure!(
                matches,
                "{}: shards={} routing={} tight={} diverged from the baseline cell's \
                 greedy outputs",
                s.name(),
                spec.shards,
                routing_name(spec.routing),
                spec.tight
            );
            let m = &run.metrics;
            let slo = m.slo_report(opts.slo);
            rep.row(vec![
                s.name().to_string(),
                spec.shards.to_string(),
                routing_name(spec.routing).to_string(),
                budget
                    .map(|(p, _)| p.to_string())
                    .unwrap_or_else(|| "∞".to_string()),
                format!("{}/{}", run.outputs.len(), trace.entries.len()),
                slo.as_ref()
                    .map(|r| format!("{:.0}", r.slo_attainment * 100.0))
                    .unwrap_or_else(|| "—".to_string()),
                slo.as_ref()
                    .map(|r| format!("{:.1}", r.goodput_rps))
                    .unwrap_or_else(|| "—".to_string()),
                format!("{:.0}", m.prefill_share_rate() * 100.0),
                m.memory_access_reduction()
                    .map(fmt_x)
                    .unwrap_or_else(|| "—".to_string()),
                m.prefill_access_reduction()
                    .map(fmt_x)
                    .unwrap_or_else(|| "—".to_string()),
            ]);
            let summary = m.scenario_summary(opts.slo);
            let Json::Obj(mut obj) = summary else {
                unreachable!("scenario_summary returns an object")
            };
            obj.insert("shards".to_string(), Json::from(spec.shards));
            obj.insert(
                "routing".to_string(),
                Json::from(routing_name(spec.routing)),
            );
            obj.insert("tight_budget".to_string(), Json::from(spec.tight));
            obj.insert("outputs_match_baseline".to_string(), Json::from(matches));
            cells_json.push(Json::Obj(obj));
            if baseline.is_none() {
                gate_scenario(s.name(), m, structural, logical)?;
                baseline = Some(run);
            }
        }
        scen_json.push(Json::from_pairs([
            ("scenario", Json::from(s.name())),
            ("description", Json::from(s.description())),
            ("entries", Json::from(trace.entries.len())),
            ("logical_tokens", Json::from(logical)),
            ("structural_shared_tokens", Json::from(structural)),
            ("cells", Json::Arr(cells_json)),
        ]));
    }
    rep.note(format!(
        "{} scenario(s) × {} cells, seed {}, open-loop {} req/s; every cell's \
         outputs matched the baseline cell bit-identically",
        scenarios.len(),
        specs.len(),
        opts.seed,
        opts.rate_rps
    ));
    rep.metrics = Some(Json::from_pairs([
        ("schema_version", Json::from(1usize)),
        ("quick", Json::from(opts.quick)),
        ("seed", Json::Num(opts.seed as f64)),
        ("rate_rps", Json::Num(opts.rate_rps)),
        (
            "slo",
            Json::from_pairs([
                ("ttft_ms", Json::Num(opts.slo.ttft_ms)),
                ("tpot_ms", Json::Num(opts.slo.tpot_ms)),
            ]),
        ),
        ("scenarios", Json::Arr(scen_json)),
    ]));
    Ok(rep)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::TraceEntry;

    fn entry(prompt: Vec<u32>) -> TraceEntry {
        TraceEntry {
            prompt,
            max_new_tokens: 4,
            at_ms: 0.0,
        }
    }

    #[test]
    fn structural_sharing_counts_best_earlier_prefix() {
        let t = Trace {
            entries: vec![
                entry(vec![1, 2, 3, 4]),
                entry(vec![1, 2, 3, 9]), // 3 shared with entry 0
                entry(vec![1, 2, 8, 8]), // 2 shared
                entry(vec![7, 7]),       // nothing shared
            ],
        };
        assert_eq!(structural_shared_tokens(&t), 5);
        assert_eq!(structural_shared_tokens(&Trace::default()), 0);
    }

    #[test]
    fn grid_starts_with_the_baseline_oracle_cell() {
        for quick in [false, true] {
            let specs = cell_specs(quick);
            assert!(specs.len() >= 3);
            assert_eq!(specs[0].shards, 1);
            assert!(!specs[0].tight);
            assert!(matches!(specs[0].routing, RoutingPolicy::Affinity));
            // Tight cells always follow an unbounded cell (their budget
            // derives from the baseline run).
            assert!(!specs.iter().take(2).any(|s| s.tight));
            assert!(specs.iter().any(|s| s.tight));
            assert!(specs.iter().any(|s| s.shards > 1));
        }
    }

    #[test]
    fn tight_budget_always_fits_the_largest_request() {
        let t = Trace {
            entries: vec![entry((0..640).collect())],
        };
        let m = Metrics::default(); // peak 0 → the floor dominates
        let (page, _swap) = tight_budget(&t, &m, 2);
        let per_req = 2 * (640 + 4usize).div_ceil(16) + 2;
        assert!(page >= 2 * 2 * per_req);
    }

    #[test]
    fn bench_model_embeds_the_default_token_span() {
        let m = bench_model();
        assert!(m.vocab > 100 + 7000, "zoo default tokens must embed");
    }
}
