//! Quickstart: the CoDec pipeline in ~60 lines — fully hermetic.
//!
//! Builds a prefix forest for three document-QA requests, plans the
//! decode-step attention with the §5 divider, executes it with the
//! native PAC/POR executor, and checks it against exact attention. No
//! artifacts directory or PJRT runtime needed. When built with
//! `--features pjrt` *and* `make artifacts` has been run, it repeats
//! the PAC/POR execution through the AOT Pallas kernels on the PJRT
//! CPU client as a cross-check.
//!
//! Run: `cargo run --release --example quickstart`

use codec::attention::codec_exec::{run_codec_attention, QueryBatch};
use codec::attention::oracle::request_attention_exact;
use codec::cost::Estimator;
use codec::kvforest::forest::StorageEvent;
use codec::kvforest::{Forest, KvStore};
use codec::sched::{divide_and_schedule, tasks_from_forest, DividerConfig};
use codec::tensor::Mat;
use codec::util::prng::Rng;

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(7);
    let (n_kv_heads, n_q_heads, d) = (2usize, 8usize, 64usize);

    // 1. Three requests asking different questions about one document.
    let mut forest = Forest::new();
    let mut store = KvStore::new(1, 16, n_kv_heads, d);
    let document: Vec<u32> = (0..500).collect();
    for r in 0..3u64 {
        let mut prompt = document.clone();
        prompt.extend(9000 + 100 * r as u32..9000 + 100 * r as u32 + 30);
        let out = forest.insert_request(r, &prompt);
        for ev in &out.events {
            store.apply(ev);
            if let StorageEvent::NeedFill { node, len } = ev {
                // Stand-in KV rows (a real engine computes them in prefill).
                for _ in 0..*len {
                    let mut k = vec![0.0; n_kv_heads * d];
                    let mut v = vec![0.0; n_kv_heads * d];
                    rng.fill_normal(&mut k, 1.0);
                    rng.fill_normal(&mut v, 1.0);
                    store.append(0, *node, &k, &v);
                }
            }
        }
    }
    println!(
        "forest: {} tokens stored once instead of {} (n̄_q = {:.1})",
        forest.total_tokens(),
        forest.logical_tokens(),
        forest.mean_sharing_degree()
    );

    // 2. One decode step's queries (one new token per request).
    let q: Vec<Mat> = (0..3)
        .map(|_| {
            let mut m = Mat::zeros(n_q_heads, d);
            rng.fill_normal(&mut m.data, 1.0);
            m
        })
        .collect();
    let batch = QueryBatch::from_parts(vec![0, 1, 2], &q, n_q_heads, n_kv_heads, d);

    // 3. Divide + schedule (§5), then execute (§4).
    let est = Estimator::table2();
    let plan = divide_and_schedule(
        tasks_from_forest(&forest, n_kv_heads, n_q_heads / n_kv_heads),
        &est,
        &DividerConfig {
            num_blocks: 8,
            min_chunk: 128,
            ..Default::default()
        },
    );
    println!(
        "plan: {} tasks → {} subtasks, predicted makespan {:.3} ms",
        plan.tasks.len(),
        plan.num_subtasks(),
        plan.makespan_ms
    );
    let outs = run_codec_attention(&forest, &store, 0, &batch, &plan, 4);

    // 4. Verify against the exact-attention oracle.
    let g = n_q_heads / n_kv_heads;
    let mut max_err = 0f32;
    for (ri, &rid) in batch.rids().iter().enumerate() {
        for kvh in 0..n_kv_heads {
            let want = request_attention_exact(
                &forest,
                &store,
                0,
                rid,
                kvh,
                &batch.group_rows(ri, kvh).to_mat(),
            );
            for j in 0..g {
                for c in 0..d {
                    max_err = max_err.max((outs[ri].at(kvh * g + j, c) - want.at(j, c)).abs());
                }
            }
        }
    }
    println!("native CoDec vs oracle: max |err| = {max_err:.2e}");
    assert!(max_err < 1e-4);

    // 5. Same attention through the AOT Pallas kernels (pjrt builds).
    pjrt_crosscheck(&forest, &store, &batch, &plan, &outs)?;
    println!("quickstart OK");
    Ok(())
}

/// Cross-check the native outputs against the AOT Pallas kernels on the
/// PJRT CPU client, when both the `pjrt` feature and artifacts exist.
#[cfg(feature = "pjrt")]
fn pjrt_crosscheck(
    forest: &Forest,
    store: &KvStore,
    batch: &QueryBatch,
    plan: &codec::sched::Plan,
    outs: &[Mat],
) -> anyhow::Result<()> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = codec::runtime::Runtime::new("artifacts")?;
        let outs_pjrt =
            codec::runtime::exec::run_codec_attention_pjrt(&rt, forest, store, 0, batch, plan)?;
        let mut diff = 0f32;
        for (a, b) in outs.iter().zip(&outs_pjrt) {
            diff = diff.max(codec::tensor::max_abs_diff(a, b));
        }
        println!("PJRT (Pallas AOT) vs native: max |err| = {diff:.2e}");
        assert!(diff < 1e-4);
    } else {
        println!("artifacts/ not built — skipping the PJRT path (run `make artifacts`)");
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_crosscheck(
    _forest: &Forest,
    _store: &KvStore,
    _batch: &QueryBatch,
    _plan: &codec::sched::Plan,
    _outs: &[Mat],
) -> anyhow::Result<()> {
    println!("built without `--features pjrt` — native path only (hermetic)");
    Ok(())
}
