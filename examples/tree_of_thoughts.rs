//! Tree-of-thoughts style branching decode (§2.5).
//!
//! Starts from one root prompt, then repeatedly *branches*: each round
//! submits several continuations that extend a previously generated
//! answer with a distinct "thought" suffix. Because every branch's prompt
//! literally begins with its parent's tokens, the radix forest deepens
//! round by round — exercising node splits, multi-level paths and the
//! tree reduction, not just the two-level doc-QA shape.
//!
//! Hermetic: runs on the native transformer backend, no artifacts.
//! Run: `cargo run --release --example tree_of_thoughts`

use codec::engine::{EngineConfig, Server};
use codec::model::Sampler;

fn main() -> anyhow::Result<()> {
    codec::util::logging::init();
    let server = Server::start(EngineConfig {
        max_batch: 9,
        sampler: Sampler::Temperature(0.9),
        seed: 3,
        ..Default::default()
    })?;

    // Root problem statement.
    let root: Vec<u32> = (1000..1096).collect();
    let branch_factor = 3;
    let rounds = 3;
    let gen_per_round = 12;

    let mut frontier: Vec<Vec<u32>> = vec![root];
    let t0 = std::time::Instant::now();
    for round in 0..rounds {
        // Each frontier prompt spawns `branch_factor` children with
        // distinct thought-separator suffixes; all children of a parent
        // share the parent's whole token sequence as a prefix.
        let mut prompts = Vec::new();
        for (pi, parent) in frontier.iter().enumerate() {
            for b in 0..branch_factor {
                let mut p = parent.clone();
                p.push(2000 + (round * 100 + pi * 10 + b) as u32); // thought marker
                prompts.push(p);
            }
        }
        // Keep the batch bounded: expand only the first few parents.
        prompts.truncate(9);
        let handles: Vec<_> = prompts
            .iter()
            .map(|p| server.submit(p.clone(), gen_per_round))
            .collect();
        let mut next = Vec::new();
        for (h, p) in handles.into_iter().zip(prompts) {
            let generated = h.wait()?;
            let mut full = p;
            full.extend(&generated);
            next.push(full);
        }
        println!(
            "round {round}: expanded {} branches (frontier prompts now {} tokens)",
            next.len(),
            next[0].len()
        );
        frontier = next;
    }
    let m = server.shutdown();
    println!("\ntree-of-thoughts stats:");
    println!(
        "  prefill: {} novel tokens vs {} reused from ancestors ({:.0}% shared)",
        m.prefill_tokens,
        m.prefill_tokens_shared,
        m.prefill_share_rate() * 100.0
    );
    if let Some(tpot) = m.mean_tpot_ms() {
        println!("  mean TPOT: {tpot:.1} ms/token");
    }
    println!("  tokens generated: {}", m.tokens_generated);
    println!("  wall: {:.2}s", t0.elapsed().as_secs_f64());
    Ok(())
}
