//! Document QA serving — the paper's motivating workload (Fig. 1a).
//!
//! Many users ask questions about the same documents. The engine's KV
//! forest stores each document once; CoDec's decode attention reads the
//! shared document KV once per step for the whole question batch.
//!
//! Hermetic by default: the transformer pieces run on the pure-Rust
//! native backend with seeded weights — no artifacts, no PJRT. The
//! `codec-pjrt` backend option needs a `--features pjrt` build plus
//! `make artifacts`.
//!
//! Run: `cargo run --release --example doc_qa [-- --backend codec|flash|codec-pjrt]`

use codec::engine::{AttentionBackend, EngineConfig, Server};
use codec::model::Sampler;
use codec::workload::{LoogleCategory, LoogleGen};

fn main() -> anyhow::Result<()> {
    codec::util::logging::init();
    let backend = match std::env::args().skip_while(|a| a != "--backend").nth(1) {
        Some(b) if b == "flash" => AttentionBackend::FlashNative,
        Some(b) if b == "codec-pjrt" => AttentionBackend::CodecPjrt,
        _ => AttentionBackend::CodecNative,
    };

    // Two "documents" (scaled-down LooGLE statistics), five questions
    // each. All ten requests decode concurrently.
    let gen = LoogleGen {
        category: LoogleCategory::Wiki,
        num_docs: 2,
        questions_per_doc: 5,
        question_tokens: 24,
        seed: 42,
        ..Default::default()
    };
    let prompts = gen.build_prompts(100); // ~210-token documents

    let server = Server::start_for(
        "artifacts",
        EngineConfig {
            backend,
            max_batch: 10,
            sampler: Sampler::Greedy,
            ..Default::default()
        },
    )?;

    println!("submitting {} questions over 2 shared documents…", prompts.len());
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| server.submit(p.clone(), 24))
        .collect();
    for h in handles {
        let id = h.id;
        let toks = h.wait()?;
        println!(
            "  answer {id}: {} tokens, first = {:?}",
            toks.len(),
            &toks[..toks.len().min(6)]
        );
    }
    let m = server.shutdown();
    println!("\nbackend {backend:?}:");
    println!(
        "  prefill: {} novel tokens, {} served from the shared prefix cache ({:.0}%)",
        m.prefill_tokens,
        m.prefill_tokens_shared,
        m.prefill_share_rate() * 100.0
    );
    if let Some(tpot) = m.mean_tpot_ms() {
        println!("  mean TPOT: {tpot:.1} ms/token");
    }
    println!("  decode throughput: {:.1} tok/s", m.decode_throughput());
    println!(
        "  division plans: {} computed, {} reused (§6 amortization)",
        m.plans_computed, m.plans_reused
    );
    println!("  wall: {:.2}s", t0.elapsed().as_secs_f64());
    Ok(())
}
