//! End-to-end validation driver — hermetic by default.
//!
//! Replays a *timed* two-wave shared-prefix trace (same documents, new
//! questions per wave, arrival offsets honored via `Server::replay`)
//! through the full stack under two attention backends and reports
//! TTFT / TPOT percentiles and KV-cache behavior side by side:
//!
//!   1. `CodecNative`  — CoDec plan + native PAC/POR
//!   2. `FlashNative`  — per-request FlashDecoding (vLLM-like baseline)
//!
//! Greedy sampling makes the generated tokens a correctness check too:
//! both backends must emit byte-identical outputs (same model, same
//! exact attention semantics). The second wave also demonstrates the
//! retained prefix cache: its document prefills are served from cache,
//! so the reported hit rate roughly doubles wave over wave. With
//! `--features pjrt` and built artifacts, a third run (`CodecPjrt` —
//! the AOT Pallas PAC/POR kernels on the PJRT client) is reported too.
//!
//! Run: `cargo run --release --example e2e_serve`

use codec::engine::{AttentionBackend, EngineConfig, Server};
use codec::model::Sampler;
use codec::workload::MultiWaveGen;

fn config(backend: AttentionBackend) -> EngineConfig {
    EngineConfig {
        backend,
        max_batch: 8,
        sampler: Sampler::Greedy, // determinism across backends
        seed: 1,
        ..Default::default()
    }
}

fn run(
    backend: AttentionBackend,
    gen: &MultiWaveGen,
) -> anyhow::Result<(Vec<Vec<u32>>, codec::engine::Metrics, f64)> {
    let server = Server::start_for("artifacts", config(backend))?;
    let t0 = std::time::Instant::now();
    let trace = gen.build_trace();
    let handles = server.replay(&trace); // honors at_ms offsets
    let mut outputs = Vec::new();
    for h in handles {
        outputs.push(h.wait()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok((outputs, server.shutdown(), wall))
}

fn pjrt_available() -> bool {
    cfg!(feature = "pjrt") && std::path::Path::new("artifacts/manifest.json").exists()
}

fn main() -> anyhow::Result<()> {
    codec::util::logging::init();
    let gen = MultiWaveGen {
        num_docs: 2,
        doc_tokens: 350,
        waves: 2,
        questions_per_doc: 4,
        question_tokens: 12,
        max_new_tokens: 16,
        wave_gap_ms: 150.0,
        intra_gap_ms: 2.0,
        seed: 11,
    };
    println!(
        "e2e: {} waves × {} requests over {} shared documents ({}-token docs), \
         {} new tokens each, timed replay\n",
        gen.waves,
        gen.num_docs * gen.questions_per_doc,
        gen.num_docs,
        gen.doc_tokens,
        gen.max_new_tokens
    );

    let mut backends = vec![AttentionBackend::CodecNative, AttentionBackend::FlashNative];
    if pjrt_available() {
        backends.push(AttentionBackend::CodecPjrt);
    } else {
        println!("(CodecPjrt run skipped: needs --features pjrt and `make artifacts`)\n");
    }

    let mut results = Vec::new();
    for backend in backends {
        println!("running backend {backend:?}…");
        let (outputs, metrics, wall) = run(backend, &gen)?;
        results.push((backend, outputs, metrics, wall));
    }

    // Correctness: greedy outputs must match bit-for-bit across every
    // backend that ran — including the PJRT composition run when
    // present (same model, same exact attention semantics).
    let reference = &results[0].1;
    for (backend, outputs, _, _) in &results[1..] {
        assert_eq!(
            outputs, reference,
            "backend {backend:?} diverged from CodecNative under greedy sampling"
        );
    }
    println!(
        "\n✓ all {} backends produced identical greedy outputs\n",
        results.len()
    );

    println!(
        "{:<14} {:>9} {:>9} {:>9} {:>9} {:>8} {:>8} {:>8}",
        "backend", "TTFT p50", "TTFT p99", "TPOT p50", "TPOT p99", "tok/s", "hit%", "wall(s)"
    );
    for (backend, _, m, wall) in &results {
        let ttft = m.ttft_summary_ms();
        let tpot = m.tpot_summary_ms();
        println!(
            "{:<14} {:>9.1} {:>9.1} {:>9.2} {:>9.2} {:>8.1} {:>8.0} {:>8.2}",
            format!("{backend:?}"),
            ttft.as_ref().map_or(f64::NAN, |s| s.p50),
            ttft.as_ref().map_or(f64::NAN, |s| s.p99),
            tpot.as_ref().map_or(f64::NAN, |s| s.p50),
            tpot.as_ref().map_or(f64::NAN, |s| s.p99),
            m.decode_throughput(),
            m.cache_hit_rate() * 100.0,
            wall
        );
    }
    let m0 = &results[0].2;
    println!(
        "\nkv cache: {} pages in use (peak {}), {:.1} MiB resident, hit rate {:.0}%",
        m0.kv_allocated_pages,
        m0.kv_max_allocated_pages,
        m0.kv_resident_bytes as f64 / (1024.0 * 1024.0),
        m0.cache_hit_rate() * 100.0
    );
    let tpot_codec = results[0].2.mean_tpot_ms().unwrap_or(f64::NAN);
    let tpot_flash = results[1].2.mean_tpot_ms().unwrap_or(f64::NAN);
    println!(
        "CoDec vs vLLM-like TPOT on this CPU testbed: {:.2}x",
        tpot_flash / tpot_codec
    );
    println!("(the paper's 3.8x is GPU-scale; see README.md for scope)");
    Ok(())
}
