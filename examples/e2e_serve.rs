//! End-to-end validation driver — hermetic by default.
//!
//! Serves a real batched document-QA workload through the full stack
//! under two attention backends and reports TPOT / throughput side by
//! side:
//!
//!   1. `CodecNative`  — CoDec plan + native PAC/POR
//!   2. `FlashNative`  — per-request FlashDecoding (vLLM-like baseline)
//!
//! Greedy sampling makes the generated tokens a correctness check too:
//! both backends must emit byte-identical outputs (same model, same
//! exact attention semantics). With `--features pjrt` and built
//! artifacts, a third run (`CodecPjrt` — the AOT Pallas PAC/POR kernels
//! on the PJRT client) is reported as well.
//!
//! Run: `cargo run --release --example e2e_serve`

use codec::engine::{AttentionBackend, EngineConfig, Server};
use codec::model::Sampler;
use codec::workload::{LoogleCategory, LoogleGen};
use std::collections::BTreeMap;

fn config(backend: AttentionBackend) -> EngineConfig {
    EngineConfig {
        backend,
        max_batch: 8,
        sampler: Sampler::Greedy, // determinism across backends
        seed: 1,
        ..Default::default()
    }
}

fn run(
    backend: AttentionBackend,
    prompts: &[Vec<u32>],
    max_new: usize,
) -> anyhow::Result<(BTreeMap<usize, Vec<u32>>, codec::engine::Metrics, f64)> {
    let server = Server::start_for("artifacts", config(backend))?;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = prompts
        .iter()
        .map(|p| server.submit(p.clone(), max_new))
        .collect();
    let mut outputs = BTreeMap::new();
    for (i, h) in handles.into_iter().enumerate() {
        outputs.insert(i, h.wait()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok((outputs, server.shutdown(), wall))
}

fn pjrt_available() -> bool {
    cfg!(feature = "pjrt") && std::path::Path::new("artifacts/manifest.json").exists()
}

fn main() -> anyhow::Result<()> {
    codec::util::logging::init();
    let gen = LoogleGen {
        category: LoogleCategory::Wiki,
        num_docs: 2,
        questions_per_doc: 4,
        question_tokens: 16,
        seed: 11,
        ..Default::default()
    };
    let prompts = gen.build_prompts(60); // ~350-token docs on CPU
    let max_new = 16;
    println!(
        "e2e: {} requests over 2 shared documents ({}-token prompts), {max_new} new tokens each\n",
        prompts.len(),
        prompts[0].len()
    );

    let mut backends = vec![AttentionBackend::CodecNative, AttentionBackend::FlashNative];
    if pjrt_available() {
        backends.push(AttentionBackend::CodecPjrt);
    } else {
        println!("(CodecPjrt run skipped: needs --features pjrt and `make artifacts`)\n");
    }

    let mut results = Vec::new();
    for backend in backends {
        println!("running backend {backend:?}…");
        let (outputs, metrics, wall) = run(backend, &prompts, max_new)?;
        results.push((backend, outputs, metrics, wall));
    }

    // Correctness: greedy outputs must match bit-for-bit across every
    // backend that ran — including the PJRT composition run when
    // present (same model, same exact attention semantics).
    let reference = &results[0].1;
    for (backend, outputs, _, _) in &results[1..] {
        assert_eq!(
            outputs, reference,
            "backend {backend:?} diverged from CodecNative under greedy sampling"
        );
    }
    println!(
        "\n✓ all {} backends produced identical greedy outputs\n",
        results.len()
    );

    println!(
        "{:<14} {:>10} {:>12} {:>10} {:>8}",
        "backend", "TPOT(ms)", "decode tok/s", "plans c/r", "wall(s)"
    );
    for (backend, _, m, wall) in &results {
        println!(
            "{:<14} {:>10.1} {:>12.1} {:>7}/{:<3} {:>8.2}",
            format!("{backend:?}"),
            m.mean_tpot_ms().unwrap_or(f64::NAN),
            m.decode_throughput(),
            m.plans_computed,
            m.plans_reused,
            wall
        );
    }
    let tpot_codec = results[0].2.mean_tpot_ms().unwrap_or(f64::NAN);
    let tpot_flash = results[1].2.mean_tpot_ms().unwrap_or(f64::NAN);
    println!(
        "\nCoDec vs vLLM-like TPOT on this CPU testbed: {:.2}x",
        tpot_flash / tpot_codec
    );
    println!("(the paper's 3.8x is GPU-scale; see README.md for scope)");
    Ok(())
}
