"""L1 correctness: the Pallas POR kernel vs the oracle, plus the algebraic
properties (associativity, commutativity, identity) that CoDec's parallel
tree reduction depends on (§4.3)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.pac import pac
from compile.kernels.por import por
from compile.kernels.ref import attention_ref, pac_ref, por_ref

RNG = np.random.default_rng(99)
NEG_INF = float("-inf")


def rand(shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, jnp.float32)


def rand_partial(nq, d, scale=1.0):
    """A random but *consistent* partial result (as PAC would emit)."""
    q, k, v = rand((nq, d), scale), rand((64, d), scale), rand((64, d))
    return pac_ref(q, k, v, 64)


def assert_close(a, b, tol=2e-5):
    for x, y in zip(a, b):
        np.testing.assert_allclose(x, y, rtol=tol, atol=tol)


class TestPorBasic:
    def test_matches_ref(self):
        p1, p2 = rand_partial(4, 64), rand_partial(4, 64)
        got = por(*p1, *p2)
        want = por_ref(*p1, *p2)
        assert_close(got, want)

    def test_commutative(self):
        p1, p2 = rand_partial(8, 64), rand_partial(8, 64)
        assert_close(por(*p1, *p2), por(*p2, *p1))

    def test_associative(self):
        p1, p2, p3 = (rand_partial(4, 64) for _ in range(3))
        left = por(*por(*p1, *p2), *p3)
        right = por(*p1, *por(*p2, *p3))
        assert_close(left, right, tol=1e-4)

    def test_identity_element(self):
        # (O=0, m=-inf, s=0) must be a two-sided identity.
        p = rand_partial(4, 64)
        zero = (jnp.zeros((4, 64), jnp.float32),
                jnp.full((4,), NEG_INF, jnp.float32),
                jnp.zeros((4,), jnp.float32))
        assert_close(por(*p, *zero), p)
        assert_close(por(*zero, *p), p)

    def test_no_nan_with_double_identity(self):
        zero = (jnp.zeros((2, 64), jnp.float32),
                jnp.full((2,), NEG_INF, jnp.float32),
                jnp.zeros((2,), jnp.float32))
        o, m, s = por(*zero, *zero)
        assert np.isfinite(np.asarray(o)).all()
        assert (np.asarray(s) == 0).all()

    def test_merge_reconstructs_full_attention(self):
        # PAC on two KV halves + POR == exact attention on the whole KV.
        q = rand((4, 64))
        k, v = rand((256, 64)), rand((256, 64))
        nv = jnp.asarray([128], jnp.int32)
        p1 = pac(q, k[:128], v[:128], nv)
        p2 = pac(q, k[128:], v[128:], nv)
        o, _, _ = por(*p1, *p2)
        np.testing.assert_allclose(o, attention_ref(q, k, v),
                                   rtol=2e-5, atol=2e-5)

    def test_skewed_magnitudes(self):
        # One side with much larger logits: merge must stay stable.
        p1 = rand_partial(4, 64, scale=10.0)
        p2 = rand_partial(4, 64, scale=0.1)
        o, m, s = por(*p1, *p2)
        assert np.isfinite(np.asarray(o)).all()
        assert_close((o, m, s), por_ref(*p1, *p2), tol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    nq=st.sampled_from([1, 3, 4, 16, 64]),
    d=st.sampled_from([64, 128]),
    s1=st.sampled_from([0.1, 1.0, 6.0]),
    s2=st.sampled_from([0.1, 1.0, 6.0]),
)
def test_por_hypothesis(nq, d, s1, s2):
    p1, p2 = rand_partial(nq, d, s1), rand_partial(nq, d, s2)
    assert_close(por(*p1, *p2), por_ref(*p1, *p2), tol=1e-4)


@settings(max_examples=10, deadline=None)
@given(splits=st.integers(min_value=2, max_value=8),
       n=st.integers(min_value=16, max_value=400))
def test_chained_por_equals_attention(splits, n):
    """Left-fold of PAC partials over arbitrary split points == attention."""
    q = rand((2, 64))
    k, v = rand((n, 64)), rand((n, 64))
    cuts = sorted({int(n * i / splits) for i in range(1, splits)} | {0, n})
    o = jnp.zeros((2, 64), jnp.float32)
    m = jnp.full((2,), NEG_INF, jnp.float32)
    s = jnp.zeros((2,), jnp.float32)
    for lo, hi in zip(cuts, cuts[1:]):
        if hi - lo < 1:
            continue
        p = pac_ref(q, k[lo:hi], v[lo:hi], hi - lo)
        o, m, s = por(o, m, s, *p)
    np.testing.assert_allclose(o, attention_ref(q, k, v), rtol=2e-5, atol=2e-5)
