"""L1 correctness: the Pallas PAC kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, valid lengths, tile sizes and input scales; every
case asserts allclose against `ref.pac_ref`. This is the core correctness
signal for the whole stack — the Rust executors are validated against the
same oracle semantics.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pac import pac
from compile.kernels.ref import attention_ref, pac_ref

RNG = np.random.default_rng(1234)


def rand(shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, jnp.float32)


def run_pac(nq, n, d, n_valid, block_k=256, scale=1.0):
    q, k, v = rand((nq, d), scale), rand((n, d), scale), rand((n, d), scale)
    o, m, s = pac(q, k, v, jnp.asarray([n_valid], jnp.int32), block_k=block_k)
    eo, em, es = pac_ref(q, k, v, n_valid)
    np.testing.assert_allclose(o, eo, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(m, em, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(s, es, rtol=5e-4, atol=5e-4)
    return q, k, v, o


class TestPacBasic:
    def test_single_query_full_valid(self):
        run_pac(1, 256, 64, 256)

    def test_multi_query(self):
        run_pac(16, 512, 128, 512)

    def test_partial_valid(self):
        run_pac(4, 512, 64, 300)

    def test_one_valid_row(self):
        # n_valid = 1: the output must equal v[0] for every query row.
        q, k, v = rand((3, 64)), rand((128, 64)), rand((128, 64))
        o, _, _ = pac(q, k, v, jnp.asarray([1], jnp.int32))
        np.testing.assert_allclose(o, jnp.broadcast_to(v[0], o.shape),
                                   rtol=1e-6, atol=1e-6)

    def test_uneven_kv_padding(self):
        # n not a multiple of block_k exercises the internal pad path.
        run_pac(2, 700, 64, 700)

    def test_valid_crosses_tile_boundary(self):
        run_pac(2, 1024, 64, 257, block_k=256)

    def test_valid_exactly_tile_boundary(self):
        run_pac(2, 1024, 64, 256, block_k=256)

    def test_matches_exact_attention(self):
        # Normalized PAC over the full valid range == exact attention.
        q, k, v = rand((8, 64)), rand((512, 64)), rand((512, 64))
        o, _, _ = pac(q, k, v, jnp.asarray([512], jnp.int32))
        np.testing.assert_allclose(o, attention_ref(q, k, v),
                                   rtol=2e-5, atol=2e-5)

    def test_large_logits_stable(self):
        # Streaming softmax must not overflow with large score magnitudes.
        q, k, v = rand((4, 64), 8.0), rand((512, 64), 8.0), rand((512, 64))
        o, m, s = pac(q, k, v, jnp.asarray([512], jnp.int32))
        assert np.isfinite(np.asarray(o)).all()
        assert np.isfinite(np.asarray(s)).all()
        eo, _, _ = pac_ref(q, k, v, 512)
        np.testing.assert_allclose(o, eo, rtol=1e-4, atol=1e-4)

    def test_block_k_invariance(self):
        # The result must not depend on the KV tile height.
        q, k, v = rand((4, 64)), rand((1024, 64)), rand((1024, 64))
        nv = jnp.asarray([777], jnp.int32)
        o1, m1, s1 = pac(q, k, v, nv, block_k=128)
        o2, m2, s2 = pac(q, k, v, nv, block_k=512)
        np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)
        np.testing.assert_allclose(m1, m2, rtol=0, atol=0)
        np.testing.assert_allclose(s1, s2, rtol=2e-4, atol=2e-4)


@settings(max_examples=25, deadline=None)
@given(
    nq=st.sampled_from([1, 2, 4, 7, 16, 33, 64]),
    n=st.integers(min_value=1, max_value=640),
    d=st.sampled_from([64, 128]),
    frac=st.floats(min_value=0.01, max_value=1.0),
    scale=st.sampled_from([0.1, 1.0, 4.0]),
)
def test_pac_hypothesis(nq, n, d, frac, scale):
    n_valid = max(1, int(n * frac))
    run_pac(nq, n, d, n_valid, scale=scale)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(min_value=2, max_value=512),
    block_k=st.sampled_from([32, 128, 256]),
)
def test_pac_tile_sweep(n, block_k):
    run_pac(3, n, 64, max(1, n - 1), block_k=block_k)
