"""L2 correctness: flash_decode composition, transformer decode-step halves,
and the dense-attention reference the Rust engine is validated against."""

import math

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels.ref import attention_ref

RNG = np.random.default_rng(7)


def rand(shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, jnp.float32)


class TestFlashDecode:
    def test_equals_attention(self):
        q, k, v = rand((4, 64)), rand((512, 64)), rand((512, 64))
        o, _, _ = M.flash_decode(q, k, v, jnp.asarray(512, jnp.int32), 4)
        np.testing.assert_allclose(o, attention_ref(q, k, v),
                                   rtol=2e-5, atol=2e-5)

    def test_partial_valid_across_splits(self):
        q, k, v = rand((2, 64)), rand((512, 64)), rand((512, 64))
        # n_valid lands inside split 2 of 4: splits 3-4 are fully masked.
        nv = 300
        o, _, _ = M.flash_decode(q, k, v, jnp.asarray(nv, jnp.int32), 4)
        np.testing.assert_allclose(o, attention_ref(q, k, v, nv),
                                   rtol=2e-5, atol=2e-5)

    @settings(max_examples=8, deadline=None)
    @given(splits=st.integers(min_value=1, max_value=6),
           nv=st.integers(min_value=1, max_value=384))
    def test_split_invariance(self, splits, nv):
        q, k, v = rand((2, 64)), rand((384, 64)), rand((384, 64))
        o, _, _ = M.flash_decode(q, k, v, jnp.asarray(nv, jnp.int32), splits)
        np.testing.assert_allclose(o, attention_ref(q, k, v, nv),
                                   rtol=2e-5, atol=2e-5)


class TestTransformerPieces:
    cfg = M.TINY
    params = M.init_params(M.TINY, seed=3)

    def test_rms_norm_unit_scale(self):
        x = rand((4, 32))
        y = M.rms_norm(x, jnp.ones((32,)))
        rms = np.sqrt(np.mean(np.asarray(y) ** 2, axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)

    def test_rope_preserves_norm(self):
        x = rand((4, 8, 64))
        y = M.rope(x, jnp.asarray([0, 5, 100, 1000], jnp.int32), 1e4)
        np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                                   np.linalg.norm(np.asarray(y), axis=-1),
                                   rtol=1e-5)

    def test_rope_position_zero_is_identity(self):
        x = rand((2, 4, 64))
        y = M.rope(x, jnp.zeros((2,), jnp.int32), 1e4)
        np.testing.assert_allclose(y, x, rtol=1e-6, atol=1e-6)

    def test_rope_relative_shift_consistency(self):
        # <q(pos+s), k(pos'+s)> must be independent of s (relative encoding).
        q = rand((1, 1, 64))
        k = rand((1, 1, 64))
        def dot(p1, p2):
            qq = M.rope(q, jnp.asarray([p1], jnp.int32), 1e4)
            kk = M.rope(k, jnp.asarray([p2], jnp.int32), 1e4)
            return float(jnp.sum(qq * kk))
        a = dot(3, 10)
        b = dot(103, 110)
        assert math.isclose(a, b, rel_tol=1e-4, abs_tol=1e-4)

    def test_attn_pre_shapes(self):
        lw = self.params["layers"][0]
        x = rand((4, self.cfg.d_model))
        pos = jnp.asarray([0, 1, 2, 3], jnp.int32)
        q, k, v = M.attn_pre(self.cfg, x, lw["ln1_w"], lw["wq"], lw["wk"],
                             lw["wv"], pos)
        assert q.shape == (4, self.cfg.n_q_heads, self.cfg.d_head)
        assert k.shape == (4, self.cfg.n_kv_heads, self.cfg.d_head)
        assert v.shape == (4, self.cfg.n_kv_heads, self.cfg.d_head)

    def test_attn_post_shapes_and_residual(self):
        lw = self.params["layers"][0]
        x = rand((2, self.cfg.d_model))
        ao = jnp.zeros((2, self.cfg.n_q_heads * self.cfg.d_head))
        y = M.attn_post(self.cfg, x, ao, lw["ln2_w"], lw["wo"],
                        lw["w_gate"], lw["w_up"], lw["w_down"])
        assert y.shape == x.shape
        # With attn_out = 0, y = x + FFN(norm(x)) — must differ from x.
        assert not np.allclose(np.asarray(y), np.asarray(x))

    def test_embed_lm_head_roundtrip_shapes(self):
        toks = jnp.asarray([1, 2, 3], jnp.int32)
        x = M.embed(toks, self.params["emb"])
        assert x.shape == (3, self.cfg.d_model)
        logits = M.lm_head(x, self.params["ln_f_w"], self.params["emb"])
        assert logits.shape == (3, self.cfg.vocab)

    def test_dense_decode_attention_vs_per_head_oracle(self):
        cfg = self.cfg
        b, n = 3, 40
        q = rand((b, cfg.n_q_heads, cfg.d_head))
        kc = rand((b, n, cfg.n_kv_heads, cfg.d_head))
        vc = rand((b, n, cfg.n_kv_heads, cfg.d_head))
        nv = jnp.asarray([40, 17, 1], jnp.int32)
        out = M.dense_decode_attention(cfg, q, kc, vc, nv)
        # Per-(request, q-head) oracle with GQA mapping.
        g = cfg.group_size
        for r in range(b):
            for h in range(cfg.n_q_heads):
                kv_h = h // g
                o = attention_ref(q[r, h][None, :], kc[r, :, kv_h, :],
                                  vc[r, :, kv_h, :], int(nv[r]))
                got = out[r, h * cfg.d_head:(h + 1) * cfg.d_head]
                np.testing.assert_allclose(got, o[0], rtol=2e-5, atol=2e-5)

    def test_gqa_group_size(self):
        assert self.cfg.group_size == 4
        assert M.QWEN3_4B.group_size == 4


class TestDecodeStepEndToEnd:
    """One full decode step through the L2 pieces, attention done the
    'engine way' (per kv-head, PAC semantics) vs dense reference."""

    def test_engine_attention_equals_dense(self):
        cfg = M.TINY
        params = M.init_params(cfg, seed=11)
        lw = params["layers"][0]
        b, n_ctx = 4, 64
        x = rand((b, cfg.d_model))
        pos = jnp.asarray([n_ctx] * b, jnp.int32)
        q, k_new, v_new = M.attn_pre(cfg, x, lw["ln1_w"], lw["wq"],
                                     lw["wk"], lw["wv"], pos)
        kc = rand((b, n_ctx + 1, cfg.n_kv_heads, cfg.d_head))
        vc = rand((b, n_ctx + 1, cfg.n_kv_heads, cfg.d_head))
        kc = kc.at[:, n_ctx].set(k_new)
        vc = vc.at[:, n_ctx].set(v_new)
        nv = jnp.asarray([n_ctx + 1] * b, jnp.int32)
        dense = M.dense_decode_attention(cfg, q, kc, vc, nv)

        # Engine-style: per (request, kv-head), stack that head-group's
        # queries and run the PAC oracle over the per-request KV.
        from compile.kernels.ref import pac_ref
        g = cfg.group_size
        out = np.zeros((b, cfg.n_q_heads * cfg.d_head), np.float32)
        for r in range(b):
            for kvh in range(cfg.n_kv_heads):
                qs = q[r, kvh * g:(kvh + 1) * g, :]       # [g, dh]
                o, _, _ = pac_ref(qs, kc[r, :, kvh, :], vc[r, :, kvh, :],
                                  n_ctx + 1)
                for j in range(g):
                    h = kvh * g + j
                    out[r, h * cfg.d_head:(h + 1) * cfg.d_head] = o[j]
        np.testing.assert_allclose(out, np.asarray(dense),
                                   rtol=2e-5, atol=2e-5)
