"""AOT pipeline checks: the emitted HLO text is loadable (no Mosaic
custom-calls, parseable header, declared shapes match the manifest)."""

import json
import os
import re

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_existing_files():
    m = manifest()
    assert len(m["artifacts"]) >= 40
    for e in m["artifacts"]:
        assert os.path.exists(os.path.join(ART, e["file"])), e["name"]


def test_bucket_grid_complete():
    m = manifest()
    names = {e["name"] for e in m["artifacts"]}
    for d in m["buckets"]["d"]:
        for nq in m["buckets"]["nq"]:
            for n in m["buckets"]["n"]:
                assert f"pac_d{d}_nq{nq}_n{n}" in names
            assert f"por_d{d}_nq{nq}" in names
    for b in m["buckets"]["batch"]:
        for piece in ("embed", "attn_pre", "attn_post", "lm_head"):
            assert f"{piece}_b{b}" in names


def test_no_mosaic_custom_calls():
    # interpret=True must fully lower Pallas; a tpu_custom_call would be
    # unloadable on the CPU PJRT plugin.
    for e in manifest()["artifacts"]:
        text = open(os.path.join(ART, e["file"])).read()
        assert "tpu_custom_call" not in text, e["name"]
        assert "mosaic" not in text.lower(), e["name"]


def test_entry_layout_matches_manifest():
    # The HLO entry computation layout must declare the manifest's input
    # shapes in order — this is what the Rust loader relies on.
    ty_re = {"f32": "f32", "i32": "s32"}
    for e in manifest()["artifacts"][:12]:
        text = open(os.path.join(ART, e["file"])).read()
        m = re.search(r"entry_computation_layout=\{\((.*?)\)->", text)
        assert m, e["name"]
        declared = m.group(1)
        for kind, shape in e["inputs"]:
            dims = ",".join(str(x) for x in shape)
            assert f"{ty_re[kind]}[{dims}]" in declared, (e["name"], shape)


def test_pac_artifact_outputs():
    for e in manifest()["artifacts"]:
        if e["kind"] != "pac":
            continue
        (o, m, s) = e["outputs"]
        assert o == ["f32", [e["nq"], e["d"]]]
        assert m == ["f32", [e["nq"]]]
        assert s == ["f32", [e["nq"]]]


def test_hlo_is_text_not_proto():
    for e in manifest()["artifacts"][:5]:
        head = open(os.path.join(ART, e["file"]), "rb").read(16)
        assert head.startswith(b"HloModule"), e["name"]
