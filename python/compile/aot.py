"""AOT compiler: lower every Layer-2 function to HLO **text** artifacts.

Run once at build time (`make artifacts`); the Rust runtime loads the text
with `HloModuleProto::from_text_file`, compiles on the PJRT CPU client, and
executes from the hot path. Python never runs at serving time.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Because PJRT executables are fixed-shape while CoDec's tasks are irregular,
we emit a *bucket grid* of PAC/POR kernels (pad + `n_valid` masking on the
Rust side) plus batch-bucketed transformer pieces for the end-to-end
engine. The bucket grid doubles as the kernel-variant sweep the paper's
task divider chooses tile configs from.

Usage:  python -m compile.aot --out-dir ../artifacts [--only pac] [--force]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels.pac import pac
from .kernels.por import por

F32 = jnp.float32
I32 = jnp.int32

# Bucket grids (see DESIGN.md §2 "Fixed-shape bucketing").
NQ_BUCKETS = [1, 4, 16, 64]
N_BUCKETS = [64, 256, 1024, 4096, 16384]
D_BUCKETS = [64, 128]
BATCH_BUCKETS = [1, 4, 8]
ENGINE_CONFIG = M.TINY


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _ty(s):
    kind = "i32" if s.dtype == jnp.int32 else "f32"
    return [kind, list(s.shape)]


def lower_entry(fn, in_specs):
    """Lower `fn` at `in_specs` and return the HLO text."""
    lowered = jax.jit(fn).lower(*in_specs)
    return to_hlo_text(lowered)


def pac_entries():
    for d in D_BUCKETS:
        for nq in NQ_BUCKETS:
            for n in N_BUCKETS:
                name = f"pac_d{d}_nq{nq}_n{n}"

                def fn(nv, q, k, v):
                    return pac(q, k, v, nv)

                yield name, fn, [spec((1,), I32), spec((nq, d)),
                                 spec((n, d)), spec((n, d))], "pac", \
                    {"d": d, "nq": nq, "n": n}


def por_entries():
    for d in D_BUCKETS:
        for nq in NQ_BUCKETS:
            name = f"por_d{d}_nq{nq}"
            yield name, por, [spec((nq, d)), spec((nq,)), spec((nq,)),
                              spec((nq, d)), spec((nq,)), spec((nq,))], \
                "por", {"d": d, "nq": nq}


def engine_entries():
    cfg = ENGINE_CONFIG
    dm, dh, dff = cfg.d_model, cfg.d_head, cfg.d_ff
    hq, hkv, v = cfg.n_q_heads, cfg.n_kv_heads, cfg.vocab
    for b in BATCH_BUCKETS:
        entries = [
            (f"embed_b{b}",
             lambda tokens, emb: M.embed(tokens, emb),
             [spec((b,), I32), spec((v, dm))]),
            (f"attn_pre_b{b}",
             lambda x, ln1, wq, wk, wv, pos: M.attn_pre(
                 cfg, x, ln1, wq, wk, wv, pos),
             [spec((b, dm)), spec((dm,)), spec((dm, hq * dh)),
              spec((dm, hkv * dh)), spec((dm, hkv * dh)), spec((b,), I32)]),
            (f"attn_post_b{b}",
             lambda x, ao, ln2, wo, wg, wu, wd: M.attn_post(
                 cfg, x, ao, ln2, wo, wg, wu, wd),
             [spec((b, dm)), spec((b, hq * dh)), spec((dm,)),
              spec((hq * dh, dm)), spec((dm, dff)), spec((dm, dff)),
              spec((dff, dm))]),
            (f"lm_head_b{b}",
             lambda x, lnf, emb: M.lm_head(x, lnf, emb),
             [spec((b, dm)), spec((dm,)), spec((v, dm))]),
        ]
        for name, fn, specs in entries:
            yield name, fn, specs, "engine", {"batch": b, "model": cfg.name}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter or one of pac|por|engine")
    ap.add_argument("--force", action="store_true",
                    help="re-emit even if the file already exists")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "buckets": {"nq": NQ_BUCKETS, "n": N_BUCKETS, "d": D_BUCKETS,
                    "batch": BATCH_BUCKETS},
        "model": {
            "name": ENGINE_CONFIG.name,
            "vocab": ENGINE_CONFIG.vocab,
            "n_layers": ENGINE_CONFIG.n_layers,
            "n_q_heads": ENGINE_CONFIG.n_q_heads,
            "n_kv_heads": ENGINE_CONFIG.n_kv_heads,
            "d_head": ENGINE_CONFIG.d_head,
            "d_ff": ENGINE_CONFIG.d_ff,
            "rope_theta": ENGINE_CONFIG.rope_theta,
        },
        "artifacts": [],
    }

    def selected(name, kind):
        return args.only is None or args.only in name or args.only == kind

    gens = [pac_entries(), por_entries(), engine_entries()]
    n_written = n_skipped = 0
    for gen in gens:
        for name, fn, specs, kind, meta in gen:
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            if os.path.exists(path) and not (args.force and selected(name, kind)):
                # Still record in the manifest, but skip re-lowering.
                text = None
            else:
                text = lower_entry(fn, specs)
            # Manifest entry needs output shapes; recompute cheaply via
            # eval_shape instead of re-lowering when the file exists.
            outs = jax.eval_shape(fn, *specs)
            entry = {
                "name": name, "file": f"{name}.hlo.txt", "kind": kind,
                "inputs": [_ty(s) for s in specs],
                "outputs": [_ty(s) for s in jax.tree_util.tree_leaves(outs)],
            }
            entry.update(meta)
            manifest["artifacts"].append(entry)
            if text is not None:
                with open(path, "w") as f:
                    f.write(text)
                n_written += 1
                print(f"  wrote {name} ({len(text)} chars)")
            else:
                n_skipped += 1

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"aot: {n_written} written, {n_skipped} up-to-date, "
          f"manifest has {len(manifest['artifacts'])} entries")


if __name__ == "__main__":
    main()
