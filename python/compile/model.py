"""Layer-2: JAX compute graphs for the CoDec stack.

Everything here is a *pure function of its inputs* (weights are arguments,
never closed over), so each function AOT-lowers to a self-contained HLO
module that the Rust runtime feeds with weight literals it generated or
loaded itself. Python never runs at serving time.

Two groups of functions:

1. Attention-core compositions over the L1 Pallas kernels (`kernels.pac`,
   `kernels.por`): `flash_decode` is the FlashDecoding baseline expressed
   as chained PAC+POR over KV splits — it exists so pytest can prove the
   streaming-softmax algebra is exact, and so the Rust baseline executor
   has a bit-accurate oracle.

2. The transformer decode step, split around the attention core exactly
   where a serving engine splits it (vLLM's "attention backend" seam):

       attn_pre : x --RMSNorm,QKV-proj,RoPE--> (q, k_new, v_new)
       [Rust: append k/v to the KV forest; CoDec PAC/POR tree attention]
       attn_post: (x, attn_out) --O-proj,residual,RMSNorm,SwiGLU--> x'

   plus `embed` and `lm_head`. The Rust engine loops layers, owning the KV
   cache between the two halves — that is precisely what lets CoDec manage
   the KV cache as a prefix forest instead of a 4D tensor.

Geometry follows Qwen3-4B's head layout (32 query heads, 8 KV heads,
d_head = 128 — the paper's default model), with layer count / widths
scaled per config for the CPU testbed.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels.pac import pac
from .kernels.por import por

NEG_INF = float("-inf")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer geometry. `name` keys the artifact manifest."""
    name: str
    vocab: int = 8192
    n_layers: int = 4
    n_q_heads: int = 8
    n_kv_heads: int = 2
    d_head: int = 64
    d_ff: int = 2816
    rope_theta: float = 10000.0

    @property
    def d_model(self) -> int:
        return self.n_q_heads * self.d_head

    @property
    def group_size(self) -> int:
        assert self.n_q_heads % self.n_kv_heads == 0
        return self.n_q_heads // self.n_kv_heads


# The end-to-end example config: ~50M params, GQA 4:1 — small enough for
# the CPU PJRT client, same head *structure* as the paper's Qwen3-4B.
TINY = ModelConfig(name="tiny", vocab=8192, n_layers=8, n_q_heads=8,
                   n_kv_heads=2, d_head=64, d_ff=2816)
# A Qwen3-4B-geometry config (32/8 heads, d_head 128) used for shape tests
# and the gpusim cost model; not AOT-compiled by default.
QWEN3_4B = ModelConfig(name="qwen3-4b", vocab=151936, n_layers=36,
                       n_q_heads=32, n_kv_heads=8, d_head=128, d_ff=9728)

CONFIGS = {c.name: c for c in (TINY, QWEN3_4B)}


# --------------------------------------------------------------------------
# Attention-core compositions (PAC / POR algebra).
# --------------------------------------------------------------------------

def flash_decode(q, k, v, n_valid, num_splits: int = 4):
    """FlashDecoding as chained PAC + POR over `num_splits` KV splits.

    Proves (and tests) the invariant CoDec relies on: splitting the KV
    sequence and POR-merging the partial outputs is exact attention.
    """
    n = k.shape[0]
    split = max(1, math.ceil(n / num_splits))
    nv_all = jnp.reshape(jnp.asarray(n_valid, jnp.int32), (1,))
    o = jnp.zeros_like(q)
    m = jnp.full((q.shape[0],), NEG_INF, jnp.float32)
    s = jnp.zeros((q.shape[0],), jnp.float32)
    for lo in range(0, n, split):
        hi = min(lo + split, n)
        nv = jnp.clip(nv_all - lo, 0, hi - lo)
        # Fully masked splits carry no mass; PAC requires >= 1 visible row,
        # so clamp and zero the result through POR's identity handling.
        oo, mm, ss = pac(q, k[lo:hi], v[lo:hi], jnp.maximum(nv, 1))
        dead = nv[0] < 1
        mm = jnp.where(dead, NEG_INF, mm)
        ss = jnp.where(dead, 0.0, ss)
        o, m, s = por(o, m, s, oo, mm, ss)
    return o, m, s


# --------------------------------------------------------------------------
# Transformer decode step (single new token per request).
# --------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6):
    """RMSNorm over the last axis."""
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, pos, theta: float):
    """Rotary position embedding. x: [B, H, Dh], pos: [B] int32."""
    _, _, dh = x.shape
    half = dh // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos[:, None].astype(jnp.float32) * freqs[None, :]     # [B, half]
    cos = jnp.cos(ang)[:, None, :]                              # [B, 1, half]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def attn_pre(cfg: ModelConfig, x, ln1_w, wq, wk, wv, pos):
    """First half of a decode-step layer: norm + QKV projections + RoPE.

    x: [B, d_model]; pos: [B] i32 (absolute position of the new token).
    Returns q [B, Hq, Dh], k_new [B, Hkv, Dh], v_new [B, Hkv, Dh]; k_new is
    post-RoPE — the KV forest stores keys rotation-applied, as vLLM does.
    """
    b = x.shape[0]
    h = rms_norm(x, ln1_w)
    q = (h @ wq).reshape(b, cfg.n_q_heads, cfg.d_head)
    k = (h @ wk).reshape(b, cfg.n_kv_heads, cfg.d_head)
    v = (h @ wv).reshape(b, cfg.n_kv_heads, cfg.d_head)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    # q is *not* pre-scaled here: PAC owns the 1/sqrt(d) scale so the same
    # kernel serves both the engine and the standalone benches.
    return q, k, v


def attn_post(cfg: ModelConfig, x, attn_out, ln2_w, wo, w_gate, w_up, w_down):
    """Second half: O-projection + residual + RMSNorm + SwiGLU + residual.

    x: [B, d_model] (the layer input), attn_out: [B, Hq*Dh].
    """
    x = x + attn_out @ wo
    h = rms_norm(x, ln2_w)
    ff = (jax.nn.silu(h @ w_gate) * (h @ w_up)) @ w_down
    return x + ff


def embed(tokens, emb):
    """Token embedding lookup. tokens: [B] i32, emb: [V, d_model]."""
    return jnp.take(emb, tokens, axis=0)


def lm_head(x, ln_f_w, emb):
    """Final norm + tied-embedding logits. Returns [B, V]."""
    return rms_norm(x, ln_f_w) @ emb.T


def dense_decode_attention(cfg: ModelConfig, q, k_cache, v_cache, n_valid):
    """Reference *dense* decode attention over a padded 4D KV cache — the
    vLLM-baseline semantics (no prefix sharing in decode). Used by pytest
    to validate that forest-based CoDec attention matches a monolithic
    cache bit-for-bit (up to fp error).

    q: [B, Hq, Dh]; k_cache/v_cache: [B, N, Hkv, Dh]; n_valid: [B] i32.
    Returns [B, Hq*Dh].
    """
    b, n = k_cache.shape[0], k_cache.shape[1]
    g = cfg.group_size
    scale = 1.0 / math.sqrt(cfg.d_head)
    kc = jnp.repeat(k_cache, g, axis=2)      # [B, N, Hq, Dh]
    vc = jnp.repeat(v_cache, g, axis=2)
    s = jnp.einsum("bhd,bnhd->bhn", q, kc) * scale
    mask = jnp.arange(n)[None, None, :] < n_valid[:, None, None]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhn,bnhd->bhd", p, vc)
    return o.reshape(b, cfg.n_q_heads * cfg.d_head)


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic random weights (for tests; the Rust engine generates
    its own with the same layer shapes — see rust/src/model)."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 16)
    dm, dff, dh = cfg.d_model, cfg.d_ff, cfg.d_head

    def mat(k, shp):
        return jax.random.normal(k, shp, jnp.float32) / math.sqrt(shp[0])

    layer = dict(
        ln1_w=jnp.ones((dm,), jnp.float32),
        wq=mat(ks[0], (dm, cfg.n_q_heads * dh)),
        wk=mat(ks[1], (dm, cfg.n_kv_heads * dh)),
        wv=mat(ks[2], (dm, cfg.n_kv_heads * dh)),
        wo=mat(ks[3], (cfg.n_q_heads * dh, dm)),
        ln2_w=jnp.ones((dm,), jnp.float32),
        w_gate=mat(ks[4], (dm, dff)),
        w_up=mat(ks[5], (dm, dff)),
        w_down=mat(ks[6], (dff, dm)),
    )
    return dict(
        emb=jax.random.normal(ks[7], (cfg.vocab, dm), jnp.float32) * 0.02,
        ln_f_w=jnp.ones((dm,), jnp.float32),
        layers=[layer for _ in range(cfg.n_layers)],
    )
