"""Layer-1 Pallas kernel: Partial Attention Computation (PAC, Algorithm 2).

PAC is the block-level primitive of CoDec: attention between a per-node
query-set tensor Q ∈ R^{nq×d} (queries of all requests whose prefix path
contains the node, stacked — §4.1 "formal per-node assembly") and that
node's KV chunk K, V ∈ R^{n×d}. It returns the *normalized* partial output
plus softmax stats (m, s) for the downstream POR tree reduction.

TPU adaptation of the paper's CUDA/CUTLASS kernel (DESIGN.md
§Hardware-Adaptation):
  * the CUDA thread block per KV tile becomes a Pallas grid step over KV
    chunks of BLOCK_K rows, with K/V tiles staged through VMEM by BlockSpec
    (the scratchpad analogue of shared memory);
  * the running-softmax accumulators (m_i, s_i, acc) live in VMEM scratch,
    exactly the registers/SMEM accumulators of FlashDecoding;
  * the Q tile is small (nq ≤ 64 after GQA stacking) and is kept resident
    for the whole grid — the paper's "load KV once, reuse for multiple
    queries" optimization is structural here: each K/V tile is read from
    HBM once for *all* nq rows;
  * the score matmul (nq×d @ d×BLOCK_K) and the value matmul are MXU-shaped
    (d = 128 lanes).

The kernel is compiled with interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls, and all performance conclusions are drawn from the
analytic model in rust/src/gpusim (see DESIGN.md).

The `n_valid` scalar makes one compiled shape serve any padded workload:
rows j >= n_valid are masked to -inf (the paper's visibility mask), so the
Rust runtime buckets irregular node sizes into a few compiled shapes.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = float("-inf")

# Default KV tile height. 256 rows × 128 lanes × 4 B = 128 KiB per K tile
# (same for V), comfortably inside a ~16 MiB VMEM budget together with the
# resident Q tile and accumulators; see DESIGN.md §Perf for the footprint
# table.
DEFAULT_BLOCK_K = 256


def _pac_kernel(nvalid_ref, q_ref, k_ref, v_ref, o_ref, m_ref, s_ref,
                acc_ref, mi_ref, si_ref, *, block_k: int, scale: float):
    """One grid step: fold KV tile j into the running softmax state."""
    j = pl.program_id(0)
    nk = pl.num_programs(0)

    @pl.when(j == 0)
    def _init():
        mi_ref[...] = jnp.full_like(mi_ref, NEG_INF)
        si_ref[...] = jnp.zeros_like(si_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                    # [nq, d]  resident across the grid
    k = k_ref[...]                    # [block_k, d] VMEM tile
    v = v_ref[...]                    # [block_k, d] VMEM tile

    # Scores for this tile, visibility-masked against n_valid.
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale        # [nq, block_k]
    offs = j * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(offs < nvalid_ref[0], s, NEG_INF)

    # Streaming-softmax update (§4.1 "streaming softmax across nodes",
    # here across tiles within the node).
    m_prev = mi_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    # n_valid >= 1 guarantees tile 0 contains a visible column, so m_new is
    # finite from the first step onward; exp(-inf - finite) = 0 handles the
    # initial m_prev = -inf and fully-masked trailing tiles.
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])                         # [nq, block_k]
    si_ref[...] = si_ref[...] * corr + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    mi_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[...] = acc_ref[...] / si_ref[...][:, None]
        m_ref[...] = mi_ref[...]
        s_ref[...] = si_ref[...]


@functools.partial(jax.jit, static_argnames=("block_k",))
def pac(q, k, v, n_valid, block_k: int = DEFAULT_BLOCK_K):
    """Partial attention computation.

    Args:
      q: [nq, d] float32 — stacked query rows of the node's query set.
      k, v: [n, d] float32 — the node's KV chunk (padded; n % block_k == 0
        after internal padding).
      n_valid: [1] int32 — number of visible KV rows (1 <= n_valid <= n).
      block_k: KV tile height.

    Returns:
      (o [nq, d], m [nq], s [nq]) — normalized partial output and softmax
      stats, exactly `ref.pac_ref`.
    """
    nq, d = q.shape
    n = k.shape[0]
    block_k = min(block_k, n)
    if n % block_k:
        pad = block_k - n % block_k
        k = jnp.pad(k, ((0, pad), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0)))
        n += pad
    grid = (n // block_k,)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(_pac_kernel, block_k=block_k, scale=scale)
    o, m, s = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda j: (0,)),              # n_valid
            pl.BlockSpec((nq, d), lambda j: (0, 0)),         # q resident
            pl.BlockSpec((block_k, d), lambda j: (j, 0)),    # k tile
            pl.BlockSpec((block_k, d), lambda j: (j, 0)),    # v tile
        ],
        out_specs=[
            pl.BlockSpec((nq, d), lambda j: (0, 0)),
            pl.BlockSpec((nq,), lambda j: (0,)),
            pl.BlockSpec((nq,), lambda j: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nq, d), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((nq, d), jnp.float32),   # acc — running numerator
            pltpu.VMEM((nq,), jnp.float32),     # running max
            pltpu.VMEM((nq,), jnp.float32),     # running denom
        ],
        interpret=True,
    )(n_valid, q, k, v)
    return o, m, s
