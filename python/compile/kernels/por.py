"""Layer-1 Pallas kernel: Partial Output Reduction (POR, Algorithm 3).

POR is the binary merge primitive of CoDec's tree reduction: it combines
two *normalized* partial outputs of the same query set — each with its
softmax stats (m, s) — into a common log-sum-exp frame:

    m = max(m1, m2)
    s = s1·e^{m1-m} + s2·e^{m2-m}
    O = (O1·s1·e^{m1-m} + O2·s2·e^{m2-m}) / s

The operation is associative and commutative (§4.3), which is what lets the
Rust reduction planner reorder the per-query node series into parallel
rounds. An identity element (s = 0, m = -inf, O = 0) is supported so the
planner can pad odd reduction rounds.

The whole working set is nq×d ≤ 64×128 floats — it trivially fits VMEM, so
the kernel runs as a single grid step (the paper runs POR entirely in
shared memory for the same reason).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = float("-inf")


def _por_kernel(o1_ref, m1_ref, s1_ref, o2_ref, m2_ref, s2_ref,
                o_ref, m_ref, s_ref):
    m1, m2 = m1_ref[...], m2_ref[...]
    s1, s2 = s1_ref[...], s2_ref[...]
    m = jnp.maximum(m1, m2)
    # Guard the (-inf) - (-inf) = nan case: a side with m_i = -inf holds no
    # probability mass and must contribute exactly 0.
    e1 = jnp.where(m1 > NEG_INF, jnp.exp(m1 - m), 0.0)
    e2 = jnp.where(m2 > NEG_INF, jnp.exp(m2 - m), 0.0)
    w1 = s1 * e1
    w2 = s2 * e2
    s = w1 + w2
    num = o1_ref[...] * w1[:, None] + o2_ref[...] * w2[:, None]
    safe = jnp.where(s > 0, s, 1.0)
    o_ref[...] = jnp.where((s > 0)[:, None], num / safe[:, None], 0.0)
    m_ref[...] = m
    s_ref[...] = s


@jax.jit
def por(o1, m1, s1, o2, m2, s2):
    """Merge two partial attention outputs (see module docstring).

    All of o1/o2: [nq, d]; m1/s1/m2/s2: [nq]. Returns (o, m, s) with the
    same shapes, exactly `ref.por_ref`.
    """
    nq, d = o1.shape
    spec2d = pl.BlockSpec((nq, d), lambda: (0, 0))
    spec1d = pl.BlockSpec((nq,), lambda: (0,))
    return pl.pallas_call(
        _por_kernel,
        in_specs=[spec2d, spec1d, spec1d, spec2d, spec1d, spec1d],
        out_specs=[spec2d, spec1d, spec1d],
        out_shape=[
            jax.ShapeDtypeStruct((nq, d), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.float32),
        ],
        interpret=True,
    )(o1, m1, s1, o2, m2, s2)
