"""Pure-jnp oracles for the CoDec kernels.

These are the correctness references the Pallas kernels (and, transitively,
the Rust-native executors) are validated against. Everything here is plain
jax.numpy with no Pallas, no tiling, no streaming — the "obviously correct"
formulation of §2.2 / Algorithms 2-3 of the paper.
"""

import jax.numpy as jnp

NEG_INF = float("-inf")


def attention_ref(q, k, v, n_valid=None):
    """Exact masked attention: softmax(q k^T / sqrt(d)) v.

    q: [nq, d], k/v: [n, d]. Positions j >= n_valid are invisible
    (mask to -inf before softmax), matching the paper's visibility mask.
    """
    n, d = k.shape
    s = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    if n_valid is not None:
        mask = jnp.arange(n) < n_valid
        s = jnp.where(mask[None, :], s, NEG_INF)
    m = jnp.max(s, axis=1, keepdims=True)
    p = jnp.exp(s - m)
    denom = jnp.sum(p, axis=1, keepdims=True)
    return (p / denom) @ v


def pac_ref(q, k, v, n_valid=None):
    """Reference PAC (Algorithm 2 + softmax stats).

    Returns the *normalized* partial output plus the per-row softmax stats
    the POR merge needs: (o [nq, d], m [nq], s [nq]) where m is the row max
    of the scaled scores and s the sum of exp(score - m) over visible
    positions.
    """
    n, d = k.shape
    scores = (q @ k.T) / jnp.sqrt(jnp.float32(d))
    if n_valid is not None:
        mask = jnp.arange(n) < n_valid
        scores = jnp.where(mask[None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=1)
    p = jnp.exp(scores - m[:, None])
    s = jnp.sum(p, axis=1)
    o = (p @ v) / s[:, None]
    return o, m, s


def por_ref(o1, m1, s1, o2, m2, s2):
    """Reference POR (Algorithm 3): merge two partial outputs of the same
    query set into a common log-sum-exp frame.

    Safe against identity elements (s = 0, m = -inf): a side with m = -inf
    contributes exactly zero.
    """
    m = jnp.maximum(m1, m2)
    # exp(m_i - m) with the (-inf) - (-inf) = nan case guarded to 0.
    e1 = jnp.where(jnp.isfinite(m1), jnp.exp(m1 - m), 0.0)
    e2 = jnp.where(jnp.isfinite(m2), jnp.exp(m2 - m), 0.0)
    s = s1 * e1 + s2 * e2
    num = o1 * (s1 * e1)[:, None] + o2 * (s2 * e2)[:, None]
    safe = jnp.where(s[:, None] > 0, s[:, None], 1.0)
    o = jnp.where(s[:, None] > 0, num / safe, 0.0)
    return o, m, s


def flash_decoding_ref(q, k, v, n_valid, num_splits):
    """FlashDecoding-style split-KV decode attention, used to check that
    chained PAC + POR over KV chunks reproduces exact attention.
    """
    n = k.shape[0]
    chunk = max(1, (n + num_splits - 1) // num_splits)
    o = jnp.zeros_like(q)
    m = jnp.full((q.shape[0],), NEG_INF, dtype=jnp.float32)
    s = jnp.zeros((q.shape[0],), dtype=jnp.float32)
    for i in range(0, n, chunk):
        hi = min(i + chunk, n)
        valid_here = max(0, min(n_valid, hi) - i)
        if valid_here == 0:
            continue
        oo, mm, ss = pac_ref(q, k[i:hi], v[i:hi], valid_here)
        o, m, s = por_ref(o, m, s, oo, mm, ss)
    return o, m, s
