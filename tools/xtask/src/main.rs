//! `cargo xtask lint` — the repo-specific architectural lint pass.
//!
//! Scans `rust/src/**` and enforces the architecture as deny-by-default
//! rules. Every rule can be waived per-site with an explicit in-source
//! annotation that names the rule and carries a non-empty reason:
//!
//! ```text
//! // lint: allow(<rule>, reason = "why this site is sound")
//! ```
//!
//! The annotation applies to its own line when trailing, or to the next
//! code line when it stands alone on a comment line. The whole
//! annotation — including the closing quote and paren — must sit on one
//! comment line.
//!
//! Rules:
//!
//! * `forest-mutation` — no direct `Forest` / `KvStore` mutation outside
//!   `cache::manager`. The serving path (`engine/`, `cache/`) must route
//!   every structural cache mutation through the manager, the single
//!   accounting point; standalone forests built by workload generators,
//!   benches, or the GPU simulator are out of scope (they never carry
//!   served traffic).
//! * `no-unwrap` — no `.unwrap()` / `.expect()` / `panic!` in non-test
//!   code under `engine/`, `cache/`, `kvforest/`. Use typed errors (or
//!   the `ShardFailure` path); annotate the few deliberate sites.
//! * `guard-across-send` — no `Mutex` guard held across a channel
//!   `.send(` / `.recv(`. Tracked lexically: a `let <name> = ….lock()…`
//!   binding is live until its block closes or an explicit
//!   `drop(<name>)`.
//! * `relaxed-ordering` — every `Ordering::Relaxed` atomic op carries a
//!   justification annotation or is upgraded to Acquire/Release.
//! * `trace-gate` — no raw trace emission (`.push_event(` or a
//!   `TraceEvent` literal) in the serving path (`engine/`, `cache/`).
//!   Those bypass the enabled-flag gate; serving code must go through
//!   `TraceRing::record` / `record_span`, which are no-ops when tracing
//!   is off — that is what keeps `--trace-out`-disabled runs free.
//! * `shared-fill-gate` — in the serving path, the shared-fill trace
//!   kinds (`SharedFill`, `FillJoin`) may only appear on a line that
//!   actually emits them (`trace_span` / `trace_event` /
//!   `record`). Naming the kind anywhere else (hand-rolled event
//!   structs, ad-hoc logging) would fork the fill-dedup telemetry away
//!   from the gated ring the CI smoke asserts on.
//!
//! Implementation note: this is a lexical scanner (comment/string-aware
//! line scan with brace-depth and `#[cfg(test)]`-region tracking), not a
//! syn AST walk — the offline hermetic build cannot vendor registry
//! crates, and the rules above are all expressible on the token stream.
//! The scanner strips string-literal contents and comments before
//! matching, so message text never false-positives a rule.
//!
//! Self-tests: `tools/xtask/fixtures/` holds one seeded violation per
//! rule plus a fully-annotated clean file; `cargo test -p xtask` asserts
//! each rule fires on its fixture and stays quiet on the clean one.

use std::collections::BTreeSet;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

const RULE_FOREST: &str = "forest-mutation";
const RULE_UNWRAP: &str = "no-unwrap";
const RULE_GUARD: &str = "guard-across-send";
const RULE_RELAXED: &str = "relaxed-ordering";
const RULE_TRACE: &str = "trace-gate";
const RULE_FILLGATE: &str = "shared-fill-gate";
/// Meta-rule: a `lint: allow` annotation that is malformed or carries an
/// empty reason is itself a violation (otherwise the allowlist rots).
const RULE_ANNOTATION: &str = "annotation";

/// Constructor / method tokens that structurally mutate `Forest` or
/// `KvStore` state. `CacheManager`'s own engine-facing API (`try_admit`,
/// `on_retire`, `append_token`, …) is deliberately absent: calling the
/// manager is the sanctioned path.
const MUTATION_TOKENS: &[&str] = &[
    "Forest::new(",
    "KvStore::new(",
    ".store_mut()",
    ".insert_request(",
    ".release_request(",
    ".remove_request(",
    ".evict_leaf(",
    ".evict_swapped(",
    ".mark_swapped(",
    ".mark_resident(",
    ".demote_node(",
    ".restore_node(",
    ".free_node(",
];

/// Tokens that emit into a trace ring without the enabled-flag gate.
/// `TraceRing::record` / `record_span` are absent: they early-return on
/// a disabled ring, so calling them is the sanctioned path.
const TRACE_TOKENS: &[&str] = &[".push_event(", "TraceEvent {", "TraceEvent{"];

/// The shared-fill trace kinds; see `shared-fill-gate`.
const FILL_KIND_TOKENS: &[&str] = &["SharedFill", "FillJoin"];
/// Emission sites that legitimately carry a shared-fill kind token.
const FILL_EMIT_TOKENS: &[&str] = &["trace_span", "trace_event", "record"];

#[derive(Debug, Clone, PartialEq, Eq)]
struct Violation {
    file: String,
    line: usize,
    rule: &'static str,
    msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.msg)
    }
}

/// Which rules apply to a file, derived from its path under `rust/src`.
#[derive(Debug, Clone, Copy)]
struct Scope {
    forest_rule: bool,
    unwrap_rule: bool,
    trace_rule: bool,
}

fn scope_for(rel: &str) -> Scope {
    let rel = rel.replace('\\', "/");
    let in_engine = rel.starts_with("engine/");
    let in_cache = rel.starts_with("cache/");
    let in_kvforest = rel.starts_with("kvforest/");
    let is_manager = rel == "cache/manager.rs";
    Scope {
        forest_rule: (in_engine || in_cache) && !is_manager,
        unwrap_rule: in_engine || in_cache || in_kvforest,
        trace_rule: in_engine || in_cache,
    }
}

/// Splits source lines into (code, comment), blanking string-literal
/// contents from the code part. State carries across lines for block
/// comments and multi-line string literals (including raw strings).
#[derive(Default)]
struct Stripper {
    in_block_comment: bool,
    in_string: bool,
    /// `Some(n)` while inside a raw string delimited by `n` hashes.
    raw_hashes: Option<usize>,
}

impl Stripper {
    fn strip(&mut self, line: &str) -> (String, String) {
        let mut code = String::new();
        let mut comment = String::new();
        let b: Vec<char> = line.chars().collect();
        let mut i = 0;
        while i < b.len() {
            if self.in_block_comment {
                if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                    self.in_block_comment = false;
                    i += 2;
                } else {
                    comment.push(b[i]);
                    i += 1;
                }
                continue;
            }
            if let Some(h) = self.raw_hashes {
                if b[i] == '"' && b[i + 1..].iter().take(h).all(|c| *c == '#') && b[i + 1..].len() >= h
                {
                    self.raw_hashes = None;
                    code.push('"');
                    i += 1 + h;
                } else {
                    i += 1;
                }
                continue;
            }
            if self.in_string {
                match b[i] {
                    '\\' => i += 2, // escape: skip the escaped char (or the line break)
                    '"' => {
                        self.in_string = false;
                        code.push('"');
                        i += 1;
                    }
                    _ => i += 1,
                }
                continue;
            }
            match b[i] {
                '/' if b.get(i + 1) == Some(&'/') => {
                    comment.extend(&b[i + 2..]);
                    break;
                }
                '/' if b.get(i + 1) == Some(&'*') => {
                    self.in_block_comment = true;
                    i += 2;
                }
                '"' => {
                    self.in_string = true;
                    code.push('"');
                    i += 1;
                }
                'r' | 'b' if self.raw_string_starts(&code, &b, i) => {
                    let mut j = i + 1;
                    if b[i] == 'b' && b.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let hashes = b[j..].iter().take_while(|c| **c == '#').count();
                    self.raw_hashes = Some(hashes);
                    code.push('"');
                    i = j + hashes + 1; // past the opening quote
                }
                '\'' => {
                    // Char literal vs lifetime.
                    if b.get(i + 1) == Some(&'\\') {
                        // `'\x'` escape literal: skip to the closing quote.
                        let mut j = i + 2;
                        while j < b.len() && b[j] != '\'' {
                            j += 1;
                        }
                        i = j + 1;
                    } else if b.get(i + 2) == Some(&'\'') {
                        i += 3; // `'x'`
                    } else {
                        code.push('\'');
                        i += 1; // lifetime
                    }
                }
                c => {
                    code.push(c);
                    i += 1;
                }
            }
        }
        (code, comment)
    }

    /// True when position `i` starts a raw (byte) string literal:
    /// `r"`, `r#…#"`, `br"`, … and the previous code char is not part of
    /// an identifier (so `for r in …` never matches).
    fn raw_string_starts(&self, code: &str, b: &[char], i: usize) -> bool {
        let prev_is_ident = code
            .chars()
            .last()
            .is_some_and(|c| c.is_alphanumeric() || c == '_');
        if prev_is_ident {
            return false;
        }
        let mut j = i + 1;
        if b[i] == 'b' {
            if b.get(j) != Some(&'r') {
                return false;
            }
            j += 1;
        }
        while b.get(j) == Some(&'#') {
            j += 1;
        }
        b.get(j) == Some(&'"')
    }
}

/// Parses every `lint: allow(<rule>, reason = "…")` annotation in a
/// comment. Returns (allowed rules, malformed-annotation messages).
fn parse_allows(comment: &str) -> (Vec<String>, Vec<String>) {
    const NEEDLE: &str = "lint: allow(";
    let mut allows = Vec::new();
    let mut errors = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find(NEEDLE) {
        let after = &rest[pos + NEEDLE.len()..];
        rest = after;
        let Some((rule, after_rule)) = after.split_once(',') else {
            errors.push("`lint: allow(…)` needs `, reason = \"…\"`".to_string());
            continue;
        };
        let rule = rule.trim().to_string();
        let reason_ok = after_rule
            .trim_start()
            .strip_prefix("reason")
            .map(str::trim_start)
            .and_then(|s| s.strip_prefix('='))
            .map(str::trim_start)
            .and_then(|s| s.strip_prefix('"'))
            .and_then(|s| s.split_once('"'))
            .is_some_and(|(reason, tail)| {
                !reason.trim().is_empty() && tail.trim_start().starts_with(')')
            });
        if reason_ok {
            allows.push(rule);
        } else {
            errors.push(format!(
                "allow({rule}) annotation requires a non-empty `reason = \"…\"` \
                 closed on the same line"
            ));
        }
    }
    (allows, errors)
}

fn binding_name(code_trim: &str) -> Option<String> {
    let rest = code_trim.strip_prefix("let ")?;
    let rest = rest.strip_prefix("mut ").unwrap_or(rest);
    let name: String = rest
        .chars()
        .take_while(|c| c.is_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

fn lint_source(file: &str, src: &str, scope: Scope) -> Vec<Violation> {
    let mut out = Vec::new();
    let mut stripper = Stripper::default();
    let mut depth: i32 = 0;
    // `#[cfg(test)]` / `#[test]` region tracking: armed by the attribute,
    // engaged at the item's opening brace, disengaged when its block
    // closes. Rules do not run inside test regions.
    let mut test_armed = false;
    let mut test_skip_depth: Option<i32> = None;
    // Allows from standalone comment lines, pending until the next code
    // line consumes them.
    let mut pending_allows: BTreeSet<String> = BTreeSet::new();
    // Live `let <name> = ….lock()…` guard bindings: (name, decl depth).
    let mut guards: Vec<(String, i32)> = Vec::new();

    for (idx, raw) in src.lines().enumerate() {
        let lineno = idx + 1;
        let (code, comment) = stripper.strip(raw);
        let code_trim = code.trim();

        let (line_allows, ann_errors) = parse_allows(&comment);
        if test_skip_depth.is_none() {
            for msg in ann_errors {
                out.push(Violation {
                    file: file.to_string(),
                    line: lineno,
                    rule: RULE_ANNOTATION,
                    msg,
                });
            }
        }

        let mut allowed: BTreeSet<String> = line_allows.into_iter().collect();
        if code_trim.is_empty() {
            pending_allows.extend(allowed);
            continue;
        }
        allowed.append(&mut pending_allows);

        if test_skip_depth.is_none() {
            if code_trim.starts_with("#[")
                && (code.contains("cfg(test)") || code.contains("#[test]"))
            {
                test_armed = true;
            }
            if test_armed {
                if code.contains('{') {
                    test_skip_depth = Some(depth);
                    test_armed = false;
                } else if !code_trim.starts_with("#[") && code.contains(';') {
                    // Attribute landed on a braceless item (`#[cfg(test)] use …;`).
                    test_armed = false;
                }
            }
        }
        let in_test = test_skip_depth.is_some();

        if !in_test {
            let mut push = |rule: &'static str, msg: String| {
                out.push(Violation {
                    file: file.to_string(),
                    line: lineno,
                    rule,
                    msg,
                });
            };
            if scope.unwrap_rule
                && (code.contains(".unwrap()")
                    || code.contains(".expect(")
                    || code.contains("panic!("))
                && !allowed.contains(RULE_UNWRAP)
            {
                push(
                    RULE_UNWRAP,
                    "`.unwrap()` / `.expect()` / `panic!` in production \
                     engine/cache/kvforest code — return a typed error, or annotate"
                        .to_string(),
                );
            }
            if code.contains("Ordering::Relaxed") && !allowed.contains(RULE_RELAXED) {
                push(
                    RULE_RELAXED,
                    "`Ordering::Relaxed` needs a justification annotation or an \
                     Acquire/Release upgrade"
                        .to_string(),
                );
            }
            if scope.forest_rule && !allowed.contains(RULE_FOREST) {
                if let Some(tok) = MUTATION_TOKENS.iter().find(|t| code.contains(**t)) {
                    push(
                        RULE_FOREST,
                        format!("direct Forest/KvStore mutation (`{tok}`) outside cache::manager"),
                    );
                }
            }
            if scope.trace_rule && !allowed.contains(RULE_TRACE) {
                if let Some(tok) = TRACE_TOKENS.iter().find(|t| code.contains(**t)) {
                    push(
                        RULE_TRACE,
                        format!(
                            "ungated trace emission (`{tok}`) in the serving path — \
                             use TraceRing::record/record_span, which no-op when \
                             tracing is disabled"
                        ),
                    );
                }
            }
            if scope.trace_rule && !allowed.contains(RULE_FILLGATE) {
                if let Some(tok) = FILL_KIND_TOKENS.iter().find(|t| code.contains(**t)) {
                    if !FILL_EMIT_TOKENS.iter().any(|t| code.contains(*t)) {
                        push(
                            RULE_FILLGATE,
                            format!(
                                "`{tok}` used away from its emission site — the \
                                 shared-fill kinds may only appear in a \
                                 trace_span/trace_event/record call so the \
                                 fill-dedup telemetry stays on the gated ring"
                            ),
                        );
                    }
                }
            }
            if (code.contains(".send(") || code.contains(".recv("))
                && !allowed.contains(RULE_GUARD)
            {
                if let Some((name, _)) = guards.first() {
                    push(
                        RULE_GUARD,
                        format!(
                            "channel op while Mutex guard `{name}` is live — drop the \
                             guard (or close its scope) before blocking"
                        ),
                    );
                }
            }
        }

        if code.contains(".lock()") && code_trim.starts_with("let ") {
            if let Some(name) = binding_name(code_trim) {
                guards.push((name, depth));
            }
        }
        if code.contains("drop(") {
            guards.retain(|(n, _)| !code.contains(&format!("drop({n})")));
        }

        let opens = code.matches('{').count() as i32;
        let closes = code.matches('}').count() as i32;
        depth += opens - closes;
        guards.retain(|(_, d)| *d <= depth);
        if test_skip_depth.is_some_and(|d| depth <= d) {
            test_skip_depth = None;
        }
    }
    out
}

fn repo_root() -> PathBuf {
    // tools/xtask/ → the repo root is two levels up.
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .ancestors()
        .nth(2)
        .expect("xtask lives two levels below the repo root")
        .to_path_buf()
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn run_lint() -> ExitCode {
    let src_root = repo_root().join("rust").join("src");
    let mut files = Vec::new();
    if let Err(e) = collect_rs_files(&src_root, &mut files) {
        eprintln!("xtask lint: cannot walk {}: {e}", src_root.display());
        return ExitCode::FAILURE;
    }
    files.sort();
    let mut violations = Vec::new();
    for path in &files {
        let rel = path
            .strip_prefix(&src_root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let src = match fs::read_to_string(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("xtask lint: cannot read {}: {e}", path.display());
                return ExitCode::FAILURE;
            }
        };
        let display = format!("rust/src/{rel}");
        violations.extend(lint_source(&display, &src, scope_for(&rel)));
    }
    if violations.is_empty() {
        println!(
            "xtask lint: {} files clean (rules: {RULE_FOREST}, {RULE_UNWRAP}, \
             {RULE_GUARD}, {RULE_RELAXED}, {RULE_TRACE}, {RULE_FILLGATE})",
            files.len()
        );
        ExitCode::SUCCESS
    } else {
        for v in &violations {
            eprintln!("{v}");
        }
        eprintln!(
            "xtask lint: {} violation(s) across {} files",
            violations.len(),
            files.len()
        );
        ExitCode::FAILURE
    }
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => run_lint(),
        _ => {
            eprintln!("usage: cargo xtask lint");
            ExitCode::from(2)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ENGINE_SCOPE: Scope = Scope {
        forest_rule: true,
        unwrap_rule: true,
        trace_rule: true,
    };

    fn fixture(name: &str) -> String {
        let path = Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("fixtures")
            .join(name);
        fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
    }

    fn rules_fired(name: &str) -> Vec<&'static str> {
        lint_source(name, &fixture(name), ENGINE_SCOPE)
            .into_iter()
            .map(|v| v.rule)
            .collect()
    }

    // --- one seeded violation per rule, each must fire -----------------

    #[test]
    fn fixture_forest_mutation_fires() {
        assert_eq!(rules_fired("forest_mutation.rs"), vec![RULE_FOREST]);
    }

    #[test]
    fn fixture_no_unwrap_fires() {
        assert_eq!(rules_fired("no_unwrap.rs"), vec![RULE_UNWRAP]);
    }

    #[test]
    fn fixture_guard_across_send_fires() {
        assert_eq!(rules_fired("guard_across_send.rs"), vec![RULE_GUARD]);
    }

    #[test]
    fn fixture_relaxed_ordering_fires() {
        assert_eq!(rules_fired("relaxed_ordering.rs"), vec![RULE_RELAXED]);
    }

    #[test]
    fn fixture_trace_gate_fires() {
        assert_eq!(rules_fired("trace_gate.rs"), vec![RULE_TRACE]);
    }

    #[test]
    fn fixture_shared_fill_gate_fires() {
        assert_eq!(rules_fired("shared_fill_gate.rs"), vec![RULE_FILLGATE]);
    }

    #[test]
    fn fixture_clean_annotated_file_passes() {
        let v = lint_source("clean.rs", &fixture("clean.rs"), ENGINE_SCOPE);
        assert!(v.is_empty(), "clean fixture flagged: {v:?}");
    }

    // --- scanner unit tests --------------------------------------------

    fn lint(src: &str) -> Vec<&'static str> {
        lint_source("t.rs", src, ENGINE_SCOPE)
            .into_iter()
            .map(|v| v.rule)
            .collect()
    }

    #[test]
    fn string_and_comment_contents_are_ignored() {
        let src = r#"
fn f() {
    let msg = "please .unwrap() the Ordering::Relaxed .send( thing";
    // and .expect( this comment mentions panic!( too
    log(msg);
}
"#;
        assert!(lint(src).is_empty());
    }

    #[test]
    fn cfg_test_modules_are_skipped() {
        let src = "
fn prod() {}
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        x.unwrap();
        y.fetch_add(1, Ordering::Relaxed);
    }
}
";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn code_after_a_test_module_is_linted_again() {
        let src = "
#[cfg(test)]
mod tests {
    fn t() { x.unwrap(); }
}
fn prod() { y.unwrap(); }
";
        assert_eq!(lint(src), vec![RULE_UNWRAP]);
    }

    #[test]
    fn trailing_allow_suppresses_its_line() {
        let src = "fn f() { x.unwrap(); } // lint: allow(no-unwrap, reason = \"test hook\")\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn standalone_allow_applies_to_next_code_line_only() {
        let src = "
// lint: allow(no-unwrap, reason = \"checked above\")
fn f() { x.unwrap(); }
fn g() { y.unwrap(); }
";
        assert_eq!(lint(src), vec![RULE_UNWRAP]);
    }

    #[test]
    fn allow_without_reason_is_itself_a_violation() {
        let src = "fn f() { y.fetch_add(1, Ordering::Relaxed); } // lint: allow(relaxed-ordering)\n";
        let fired = lint(src);
        assert!(fired.contains(&RULE_ANNOTATION), "fired: {fired:?}");
        assert!(fired.contains(&RULE_RELAXED), "fired: {fired:?}");
    }

    #[test]
    fn allow_with_empty_reason_is_rejected() {
        let src =
            "fn f() { x.unwrap(); } // lint: allow(no-unwrap, reason = \"  \")\n";
        assert!(lint(src).contains(&RULE_ANNOTATION));
    }

    #[test]
    fn guard_dropped_before_send_is_clean() {
        let src = "
fn f() {
    let g = m.lock();
    drop(g);
    tx.send(1);
}
";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn guard_scope_closed_before_send_is_clean() {
        let src = "
fn f() {
    let shard = {
        let g = m.lock();
        g.pick()
    };
    tx.send(shard);
}
";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn guard_live_across_send_fires() {
        let src = "
fn f() {
    let g = m.lock();
    tx.send(1);
}
";
        assert_eq!(lint(src), vec![RULE_GUARD]);
    }

    #[test]
    fn forest_rule_respects_scope() {
        let src = "fn f(c: &mut M) { c.store_mut().append(1); }\n";
        assert_eq!(lint(src), vec![RULE_FOREST]);
        let manager = scope_for("cache/manager.rs");
        assert!(lint_source("m.rs", src, manager).is_empty());
        let kvforest = scope_for("kvforest/forest.rs");
        assert!(lint_source("f.rs", src, kvforest).is_empty());
    }

    #[test]
    fn shared_fill_kind_on_emission_line_is_clean() {
        let src = "fn f(e: &mut Engine) { e.trace_span(EventKind::SharedFill, 0, 1, 5, 3); }\n";
        assert!(lint(src).is_empty());
        let src = "fn f(e: &mut Engine) { e.trace_event(EventKind::FillJoin, 2, 5, 9); }\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn shared_fill_kind_off_emission_line_fires() {
        let src = "fn f() { let k = EventKind::FillJoin; stash(k); }\n";
        assert_eq!(lint(src), vec![RULE_FILLGATE]);
        // Out of serving scope (obs/) the rule does not apply.
        let obs = scope_for("obs/trace.rs");
        assert!(lint_source("t.rs", src, obs).is_empty());
    }

    #[test]
    fn unwrap_like_names_do_not_fire() {
        let src = "fn f() { x.unwrap_or(0); y.unwrap_or_else(g); z.expect_err_helper(); }\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn multi_line_string_literals_do_not_leak_into_code() {
        let src = "
fn f() {
    let s = \"first line .unwrap()
        still inside the literal Ordering::Relaxed
        done\";
    use_it(s);
}
";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn raw_strings_are_blanked() {
        let src = "fn f() { let s = r#\"json .unwrap() \"quoted\" panic!(\"#; use_it(s); }\n";
        assert!(lint(src).is_empty());
    }

    #[test]
    fn scope_mapping_matches_the_layout() {
        assert!(scope_for("engine/server.rs").forest_rule);
        assert!(scope_for("engine/server.rs").unwrap_rule);
        assert!(scope_for("engine/server.rs").trace_rule);
        assert!(!scope_for("cache/manager.rs").forest_rule);
        assert!(scope_for("cache/manager.rs").unwrap_rule);
        assert!(scope_for("cache/manager.rs").trace_rule);
        assert!(!scope_for("kvforest/forest.rs").forest_rule);
        assert!(scope_for("kvforest/forest.rs").unwrap_rule);
        assert!(!scope_for("kvforest/forest.rs").trace_rule);
        // The recorder itself lives in obs/: raw inserts are legal there.
        assert!(!scope_for("obs/trace.rs").trace_rule);
        assert!(!scope_for("util/threadpool.rs").forest_rule);
        assert!(!scope_for("util/threadpool.rs").unwrap_rule);
        assert!(!scope_for("util/threadpool.rs").trace_rule);
    }
}
