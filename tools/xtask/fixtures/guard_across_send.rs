// Seeded violation for the `guard-across-send` rule: a Mutex guard
// still live at a channel send (the send blocks while the lock is held).

fn hold_guard_over_send(m: &Mutex<u32>, tx: &Sender<u32>) {
    let guard = m.lock();
    tx.send(*guard);
}
