// Seeded violation for the `forest-mutation` rule: engine-scope code
// reaching past the cache manager straight into the paged store.

fn bypass_the_manager(cache: &mut CacheManager) {
    cache.store_mut().append(0, 1, &[0.0]);
}
