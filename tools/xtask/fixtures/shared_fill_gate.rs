// Seeded violation for the `shared-fill-gate` rule: engine-scope code
// naming a shared-fill trace kind away from a trace_span/trace_event/
// record emission site, forking the fill-dedup telemetry off the ring.

fn stash_kind_for_later(slot: &mut Option<EventKind>) {
    *slot = Some(EventKind::SharedFill);
}
