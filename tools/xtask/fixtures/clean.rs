// Fully-annotated twin of the seeded-violation fixtures: every pattern
// the linter denies appears here either with the allowlist annotation or
// in the sound structure, so this file must produce zero violations.

fn annotated_unwrap(x: Option<u32>) -> u32 {
    // lint: allow(no-unwrap, reason = "fixture: the invariant is documented here")
    x.unwrap()
}

fn annotated_mutation(cache: &mut CacheManager) {
    // lint: allow(forest-mutation, reason = "fixture: sanctioned append seam")
    cache.store_mut().append(0, 1, &[0.0]);
}

fn annotated_relaxed(counter: &AtomicUsize) {
    counter.fetch_add(1, Ordering::Relaxed); // lint: allow(relaxed-ordering, reason = "advisory counter")
}

fn annotated_trace_emit(ring: &mut TraceRing, ev: TraceEvent) {
    // lint: allow(trace-gate, reason = "fixture: replaying an already-gated event")
    ring.push_event(ev);
}

fn sanctioned_shared_fill_emission(eng: &mut Engine) {
    eng.trace_span(EventKind::SharedFill, 0, 1, 5, 3);
    eng.trace_event(EventKind::FillJoin, 2, 5, 120);
}

fn guard_scoped_before_send(m: &Mutex<u32>, tx: &Sender<u32>) {
    let v = {
        let guard = m.lock();
        *guard
    };
    tx.send(v);
}

fn string_contents_never_fire() -> &'static str {
    "mentions .unwrap() and Ordering::Relaxed and .send( harmlessly"
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        Some(1).unwrap();
        counter.fetch_add(1, Ordering::Relaxed);
    }
}
