// Seeded violation for the `relaxed-ordering` rule: a Relaxed atomic op
// with no justification annotation.

fn bump(counter: &AtomicUsize) {
    counter.fetch_add(1, Ordering::Relaxed);
}
