// Seeded violation for the `trace-gate` rule: engine-scope code pushing
// a raw event into the ring, bypassing the enabled-flag gate that keeps
// disabled tracing free.

fn bypass_the_gate(ring: &mut TraceRing) {
    ring.push_event(make_event());
}
