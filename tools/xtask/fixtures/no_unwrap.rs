// Seeded violation for the `no-unwrap` rule: a naked unwrap in
// "production" engine-scope code. The xtask self-test asserts the rule
// fires here (and nowhere else in this file).

fn production_path(x: Option<u32>) -> u32 {
    x.unwrap()
}
